//! Table 1: final train loss / eval acc / FLOPs reduction across the task
//! suite for exact / SB / UB / VCAS.
//!
//! Reproduction claim (shape, not absolute numbers): VCAS's loss and acc
//! stay closest to exact among the sampling methods while it reports a
//! comparable FLOPs reduction; SB degrades loss the most; VCAS's reduction
//! adapts per task (harder task -> smaller reduction).

mod common;

use vcas::config::Method;

fn main() {
    let engine = common::load_backend();
    let steps = common::bench_steps(400);
    let tasks = ["sst2-sim", "qnli-sim", "qqp-sim", "mnli-sim"];
    let mut table = common::Table::new(&[
        "task", "method", "final loss", "eval acc", "FLOPs red.", "steady-state", "wall s",
    ]);
    let mut rows = Vec::new();

    for task in tasks {
        let mut exact_loss = 0.0;
        for method in [Method::Exact, Method::Sb, Method::Ub, Method::Vcas] {
            let cfg = common::base_config("tiny", task, method.clone(), steps, 1);
            let r = common::run(&engine, &cfg);
            if method == Method::Exact {
                exact_loss = r.final_train_loss;
            }
            table.row(vec![
                task.into(),
                r.method.clone(),
                format!("{:.4} ({:+.4})", r.final_train_loss, r.final_train_loss - exact_loss),
                common::pct(r.final_eval_acc),
                common::pct(r.flops_reduction),
                common::pct(r.steady_state_reduction()),
                format!("{:.1}", r.wall_s),
            ]);
            rows.push((
                task.to_string(),
                r.method.clone(),
                r.final_train_loss,
                r.final_eval_acc,
                r.flops_reduction,
                r.wall_s,
            ));
        }
    }
    table.print(&format!("Table 1 — task suite, {steps} steps (paper protocol, scaled)"));
    common::write_summary_csv("table1", &rows);
}
