//! Fig. 1 / Fig. 6: convergence trajectories (train loss vs steps and vs
//! normalized FLOPs) for exact / SB / UB / VCAS on the MNLI-sim task.
//!
//! Reproduction claim: VCAS's loss-vs-steps curve tracks exact while its
//! FLOPs axis is compressed; SB diverges to a different trajectory; UB
//! lags. Series land in results/fig1_*.csv.

mod common;

use vcas::config::Method;

fn main() {
    let engine = common::load_backend();
    let steps = common::bench_steps(240);
    let mut table = common::Table::new(&["method", "loss@25%", "loss@50%", "final", "FLOPs vs exact"]);

    let mut exact_flops = 0.0;
    for method in [Method::Exact, Method::Sb, Method::Ub, Method::Vcas] {
        let cfg = common::base_config("tiny", "mnli-sim", method.clone(), steps, 11);
        let r = common::run(&engine, &cfg);
        common::copy_loss_csv(&r, &format!("fig1_{}", r.method));
        if method == Method::Exact {
            exact_flops = r.flops_actual;
        }
        let at = |frac: f64| {
            let i = ((steps as f64 * frac) as usize).min(steps - 1);
            // smooth over a window to make the table readable
            let lo = i.saturating_sub(8);
            let w = &r.losses[lo..=i];
            w.iter().map(|&(_, l)| l as f64).sum::<f64>() / w.len() as f64
        };
        table.row(vec![
            r.method.clone(),
            common::f4(at(0.25)),
            common::f4(at(0.5)),
            common::f4(r.final_train_loss),
            format!("{:.3}x", r.flops_actual / exact_flops),
        ]);
    }
    table.print(&format!(
        "Fig. 1/6 — convergence on mnli-sim ({steps} steps); VCAS should track exact"
    ));
    println!("per-step series: results/fig1_<method>.csv (loss + cumulative FLOPs)");
}
