//! Fig. 5: gradient variance of each method during training.
//!
//! At intervals, measure (a) the SGD variance across fresh batches and
//! (b) the method's extra estimator variance on a fixed batch. Reproduction
//! claim: VCAS keeps v_extra pinned near tau * v_sgd; SB/UB's extra
//! variance is uncontrolled (orders of magnitude larger) at similar FLOPs.

mod common;

use vcas::config::Method;
use vcas::coordinator::Trainer;
use vcas::formats::csv::{CsvField, CsvWriter};

fn main() {
    let engine = common::load_backend();
    let steps = common::bench_steps(180);
    let snaps = 4usize;
    let chunk = steps / snaps;
    let reps = 6usize;

    let path = common::results_dir().join("fig5_variance.csv");
    let mut csv =
        CsvWriter::create(&path, &["method", "step", "v_sgd", "v_extra", "ratio"]).unwrap();
    let mut table = common::Table::new(&["method", "step", "v_sgd", "v_extra", "extra/sgd"]);

    for method in [Method::Vcas, Method::Ub, Method::Sb, Method::Uniform] {
        let cfg = common::base_config("tiny", "mnli-sim", method.clone(), steps, 9);
        let mut trainer = Trainer::new(&engine, &cfg).unwrap();
        for snap in 0..snaps {
            trainer.advance(chunk).unwrap();
            let v = trainer.measure_variance(reps).unwrap();
            let ratio = v.v_extra / v.v_sgd.max(1e-12);
            csv.row_mixed(&[
                CsvField::Str(method.name().into()),
                CsvField::I(((snap + 1) * chunk) as i64),
                CsvField::F(v.v_sgd),
                CsvField::F(v.v_extra),
                CsvField::F(ratio),
            ])
            .unwrap();
            table.row(vec![
                method.name().into(),
                format!("{}", (snap + 1) * chunk),
                format!("{:.3e}", v.v_sgd),
                format!("{:.3e}", v.v_extra),
                format!("{:.3}", ratio),
            ]);
        }
    }
    csv.flush().unwrap();
    table.print("Fig. 5 — extra variance / SGD variance (VCAS pinned near tau=0.05 total; SB/UB uncontrolled)");
    println!("series: {}", path.display());
}
