//! Tables 2/3: wall-clock time vs FLOPs reduction.
//!
//! Reproduction claim: the sampling methods translate FLOPs reduction into
//! wall-clock reduction at comparable rates (Time-red% < FLOPs-red%,
//! Amdahl: the forward pass and the coordinator are not reduced), with
//! VCAS competitive with SB/UB. The static-shape runtime realizes the
//! backward shrink through the sub-batch executable for SB/UB; VCAS's
//! mask-based estimator runs full-shape (its wall-clock here reflects the
//! probe overhead only — DESIGN.md §4.3 discusses shape-bucketed variants
//! for hardware realization).

mod common;

use vcas::config::Method;

fn main() {
    let engine = common::load_backend();
    let steps = common::bench_steps(200);
    let mut table = common::Table::new(&[
        "method", "train loss", "eval acc", "wall s", "FLOPs red.", "time red.",
    ]);
    let mut rows = Vec::new();

    // Warmup: compile every entry + touch every code path so the timed
    // runs measure steady-state step cost, not one-time XLA compilation.
    for method in [Method::Exact, Method::Sb, Method::Ub, Method::Vcas] {
        let mut w = common::base_config("tiny", "mnli-sim", method, 4, 2);
        w.vcas.freq = 2;
        let _ = common::run(&engine, &w);
    }

    let mut exact_wall = 0.0;
    for method in [Method::Exact, Method::Sb, Method::Ub, Method::Vcas] {
        let cfg = common::base_config("tiny", "mnli-sim", method.clone(), steps, 2);
        let r = common::run(&engine, &cfg);
        if method == Method::Exact {
            exact_wall = r.wall_s;
        }
        let time_red = 1.0 - r.wall_s / exact_wall;
        table.row(vec![
            r.method.clone(),
            common::f4(r.final_train_loss),
            common::pct(r.final_eval_acc),
            format!("{:.1}", r.wall_s),
            common::pct(r.flops_reduction),
            common::pct(time_red),
        ]);
        rows.push((
            "mnli-sim".to_string(),
            r.method.clone(),
            r.final_train_loss,
            r.final_eval_acc,
            r.flops_reduction,
            r.wall_s,
        ));
    }
    table.print(&format!("Tables 2/3 — wall-clock vs FLOPs ({steps} steps, CPU PJRT)"));
    common::write_summary_csv("table2_walltime", &rows);
}
