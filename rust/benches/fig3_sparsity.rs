//! Fig. 3: gradient-norm distribution over layers and iterations.
//!
//! Trains exact and snapshots the per-layer per-sample activation-gradient
//! norms at intervals; emits the heatmap data (normalized norms + the 95%
//! mass percentile) to results/fig3_heatmap.csv. Reproduction claim: the
//! distribution sharpens (sparsity grows) toward lower layers and as
//! training progresses.

mod common;

use vcas::config::Method;
use vcas::coordinator::Trainer;
use vcas::formats::csv::{CsvField, CsvWriter};
use vcas::runtime::Backend;
use vcas::util::stats::mass_fraction;

fn main() {
    let engine = common::load_backend();
    let steps = common::bench_steps(240);
    let snaps = 6usize;
    let chunk = steps / snaps;

    let cfg = common::base_config("tiny", "sst2-sim", Method::Exact, steps, 3);
    let mut trainer = Trainer::new(&engine, &cfg).unwrap();

    let path = common::results_dir().join("fig3_heatmap.csv");
    let mut csv = CsvWriter::create(&path, &["iter", "layer", "p95_mass_fraction", "top1_share"])
        .unwrap();

    let mut table = common::Table::new(&["iteration", "p_l(0.95) per layer (bottom->top)"]);
    for snap in 0..snaps {
        let _ = trainer.advance(chunk).unwrap();
        let snap_probe = trainer.measure_sparsity().unwrap();
        let n = engine.main_batch();
        let n_layers = snap_probe.len() / n;
        let mut row = Vec::new();
        for l in 0..n_layers {
            let norms = &snap_probe[l * n..(l + 1) * n];
            let p95 = mass_fraction(norms, 0.95);
            let total: f64 = norms.iter().map(|&x| x as f64).sum();
            let top1 = norms.iter().cloned().fold(0.0f32, f32::max) as f64 / total.max(1e-12);
            csv.row_mixed(&[
                CsvField::I(((snap + 1) * chunk) as i64),
                CsvField::I(l as i64),
                CsvField::F(p95),
                CsvField::F(top1),
            ])
            .unwrap();
            row.push(format!("{p95:.2}"));
        }
        table.row(vec![format!("{}", (snap + 1) * chunk), row.join(" ")]);
    }
    csv.flush().unwrap();
    table.print("Fig. 3 — gradient-norm sparsity p_l(s=0.95): lower layers & later iters get sparser");
    println!("heatmap data: {}", path.display());
}
