//! Figs. 9/10 (Appendix A.4): grid over the s step alpha and the weight
//! ratio multiplier beta.
//!
//! Reproduction claim: more aggressive settings (larger alpha, smaller
//! beta) buy FLOPs at a small loss cost; all cells stay within a modest
//! accuracy band — robustness of the zeroth-order controller.

mod common;

use vcas::config::Method;

fn main() {
    let engine = common::load_backend();
    let steps = common::bench_steps(160);
    let alphas = [0.005, 0.01, 0.02];
    let betas = [0.95, 0.9, 0.8];
    let mut table = common::Table::new(&["alpha", "beta", "final loss", "eval acc", "FLOPs red."]);
    let mut rows = Vec::new();

    for &alpha in &alphas {
        for &beta in &betas {
            let mut cfg = common::base_config("tiny", "sst2-sim", Method::Vcas, steps, 7);
            cfg.vcas.alpha = alpha;
            cfg.vcas.beta = beta;
            let r = common::run(&engine, &cfg);
            table.row(vec![
                alpha.to_string(),
                beta.to_string(),
                common::f4(r.final_train_loss),
                common::pct(r.final_eval_acc),
                common::pct(r.flops_reduction),
            ]);
            rows.push((
                "sst2-sim".to_string(),
                format!("a={alpha},b={beta}"),
                r.final_train_loss,
                r.final_eval_acc,
                r.flops_reduction,
                r.wall_s,
            ));
        }
    }
    table.print(&format!("Figs. 9/10 — alpha x beta grid ({steps} steps)"));
    common::write_summary_csv("ablation_alpha_beta", &rows);
}
