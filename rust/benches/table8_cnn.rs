//! Table 8 (Appendix C): CNN pretraining with SGDM under the *degraded*
//! activation-only VCAS, plus the data-parallel coordinator cost model.
//!
//! Reproduction claim: VCAS matches exact's loss/acc with a moderate FLOPs
//! reduction (smaller than the transformer runs — no SampleW on convs),
//! and the allreduce combine adds only O(log W) depth (Amdahl's law keeps
//! time reduction below FLOPs reduction, as in the paper's 8-GPU row).

mod common;

use std::sync::Arc;

use vcas::config::Method;
use vcas::coordinator::comm::{BucketPlan, ReduceOptions, DEFAULT_BUCKET_BYTES};
use vcas::coordinator::parallel::{
    data_parallel_grads, data_parallel_grads_overlapped, data_parallel_grads_streamed,
    tree_allreduce_mean, tree_depth,
};
use vcas::coordinator::pipeline::{sharded_streams, BatchSource, ImgSource};
use vcas::data::batch::gather_img;
use vcas::data::images::{generate_images, ImageSpec};
use vcas::runtime::{Backend, NativeBackend};
use vcas::util::rng::Pcg32;

fn main() {
    let engine = common::load_backend();
    let steps = common::bench_steps(120);
    let mut table = common::Table::new(&[
        "method", "train loss", "eval acc", "FLOPs red.", "wall s",
    ]);
    let mut rows = Vec::new();

    for method in [Method::Exact, Method::Vcas] {
        let mut cfg = common::base_config("cnn", "images", method.clone(), steps, 3);
        cfg.optim.kind = "sgdm".into();
        cfg.optim.lr = 0.05;
        let r = common::run(&engine, &cfg);
        table.row(vec![
            r.method.clone(),
            common::f4(r.final_train_loss),
            common::pct(r.final_eval_acc),
            common::pct(r.flops_reduction),
            format!("{:.1}", r.wall_s),
        ]);
        rows.push((
            "images".to_string(),
            r.method.clone(),
            r.final_train_loss,
            r.final_eval_acc,
            r.flops_reduction,
            r.wall_s,
        ));
    }
    table.print(&format!(
        "Table 8 — CNN + SGDM, activation-only VCAS ({steps} steps)"
    ));
    common::write_summary_csv("table8_cnn", &rows);

    // DDP comm model: measure the tree allreduce on CNN-sized grads
    let info = engine.info("cnn").unwrap();
    let n_params: usize = info.total_elems();
    let mut rng = Pcg32::new(1, 1);
    let mut comm = common::Table::new(&["workers", "tree depth", "allreduce ms"]);
    for w in [2usize, 4, 8] {
        let grads: Vec<Vec<Vec<f32>>> = (0..w)
            .map(|_| vec![(0..n_params).map(|_| rng.f32()).collect()])
            .collect();
        let ms = common::time_median_ms(5, || {
            let _ = tree_allreduce_mean(grads.clone()).unwrap();
        });
        comm.row(vec![w.to_string(), tree_depth(w).to_string(), format!("{ms:.2}")]);
    }
    comm.print(&format!("Table 8 (cont.) — DDP allreduce cost, {n_params} params"));

    // Real-thread DDP round: wall-clock of shard grads + combine as worker
    // threads scale (the Amdahl story next to the FLOPs table above). Runs
    // on the native backend with 1 kernel thread per worker so the DDP
    // workers, not the kernel layer, own the cores — dims must come from
    // the native registry, which can differ from an artifact-scale engine.
    let native = NativeBackend::with_default_models().with_threads(1);
    let native_info = native.info("cnn").unwrap();
    let params = native.init_params("cnn").unwrap();
    let spec = ImageSpec {
        img: native_info.img,
        channels: native_info.in_ch,
        n_classes: native_info.n_classes,
        ..ImageSpec::default()
    };
    let max_workers = 8usize;
    let ds = generate_images(&spec, native.cnn_batch() * max_workers, 13);
    let rho = vec![1.0f32; native_info.n_layers];
    let mut ddp = common::Table::new(&["workers", "round ms", "notes"]);
    for w in [1usize, 2, 4, 8] {
        let ms = common::time_median_ms(5, || {
            let _ = data_parallel_grads(w, ds.n, |wk, (s, e)| {
                let idx: Vec<usize> = (s..e).collect();
                let batch = gather_img(&ds, &idx);
                native.cnn_fwd_bwd("cnn", &params, &batch, wk as i32, &rho).map(|o| o.grads)
            })
            .unwrap();
        });
        ddp.row(vec![
            w.to_string(),
            format!("{ms:.1}"),
            "fixed total batch, real threads".into(),
        ]);
    }
    ddp.print("Table 8 (cont.) — real-thread DDP round, fixed total batch");

    // Streamed DDP round: each worker pulls its shard from its own
    // prefetch queue (depth 2) instead of waiting on a leader gather —
    // same tree combine, bitwise-identical round, host-side batch work
    // overlapped with the previous round's compute.
    let ds = Arc::new(ds);
    let mut ddp_s = common::Table::new(&["workers", "round ms", "notes"]);
    for w in [1usize, 2, 4, 8] {
        let mut shards = sharded_streams(w, ds.n, 2, |range| {
            Box::new(ImgSource::new(ds.clone(), ds.n, 29).with_shard(range))
                as Box<dyn BatchSource>
        });
        // one warm round lets the producers fill their queues
        let _ = data_parallel_grads_streamed(&mut shards, |wk, b| {
            let batch = b.into_img()?;
            native.cnn_fwd_bwd("cnn", &params, &batch, wk as i32, &rho).map(|o| o.grads)
        })
        .unwrap();
        let ms = common::time_median_ms(5, || {
            let _ = data_parallel_grads_streamed(&mut shards, |wk, b| {
                let batch = b.into_img()?;
                native.cnn_fwd_bwd("cnn", &params, &batch, wk as i32, &rho).map(|o| o.grads)
            })
            .unwrap();
        });
        ddp_s.row(vec![
            w.to_string(),
            format!("{ms:.1}"),
            "sharded prefetch streams, depth 2".into(),
        ]);
    }
    ddp_s.print("Table 8 (cont.) — streamed DDP round (prefetch queues, no leader gather)");

    // Overlapped DDP round: per-layer gradients publish into the bucketed
    // comm scheduler as the backward produces them, so the tree combine
    // runs while earlier layers still compute. Same tree, same buckets in
    // flat order — the round result is bitwise identical to the
    // sequential rounds above; only wall-clock moves. Staging buffers come
    // from the backend's workspace, so steady-state rounds stop
    // allocating.
    let plan = BucketPlan::for_model(&native_info, DEFAULT_BUCKET_BYTES).unwrap();
    let opts = ReduceOptions { workspace: Some(native.workspace()), ..ReduceOptions::default() };
    let mut ddp_o = common::Table::new(&["workers", "round ms", "notes"]);
    for w in [1usize, 2, 4, 8] {
        // warm round fills the workspace pool
        let _ = data_parallel_grads_overlapped(w, ds.n, &plan, &opts, |wk, (s, e), p| {
            let idx: Vec<usize> = (s..e).collect();
            let batch = gather_img(&ds, &idx);
            native.cnn_fwd_bwd_hooked("cnn", &params, &batch, wk as i32, &rho, p).map(|_| ())
        })
        .unwrap();
        let ms = common::time_median_ms(5, || {
            let _ = data_parallel_grads_overlapped(w, ds.n, &plan, &opts, |wk, (s, e), p| {
                let idx: Vec<usize> = (s..e).collect();
                let batch = gather_img(&ds, &idx);
                native.cnn_fwd_bwd_hooked("cnn", &params, &batch, wk as i32, &rho, p).map(|_| ())
            })
            .unwrap();
        });
        ddp_o.row(vec![
            w.to_string(),
            format!("{ms:.1}"),
            format!("bucketed overlap, {} buckets", plan.n_buckets()),
        ]);
    }
    ddp_o.print("Table 8 (cont.) — overlapped DDP round (bucketed reduce during backward)");
}
