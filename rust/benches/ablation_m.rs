//! Figs. 7/8 (Appendix A.2): Monte-Carlo repetitions M.
//!
//! Reproduction claim: the empirical variance estimates (V_s, V_act) are
//! stable across M in {2..6} — M=2 suffices, so probe overhead stays
//! negligible.

mod common;

use vcas::config::Method;

fn main() {
    let engine = common::load_backend();
    let steps = common::bench_steps(120);
    let mut table =
        common::Table::new(&["M", "V_s (last probe)", "V_act (last)", "V_act/V_s", "actual/exact FLOPs"]);

    for m in [2usize, 3, 4, 6] {
        let mut cfg = common::base_config("tiny", "sst2-sim", Method::Vcas, steps, 8);
        cfg.vcas.m_repeats = m;
        let r = common::run(&engine, &cfg);
        let p = r.probes.last().unwrap();
        let actual_share = r.flops_actual / r.flops_exact; // grows O(M^2)
        table.row(vec![
            m.to_string(),
            format!("{:.4e}", p.v_s),
            format!("{:.4e}", p.v_act),
            format!("{:.4}", p.v_act / p.v_s.max(1e-12)),
            common::pct(actual_share),
        ]);
    }
    table.print(&format!(
        "Figs. 7/8 — variance estimates stable in M; probe cost grows O(M^2) ({steps} steps)"
    ));
}
