//! Tables 4/5 (Appendix A.1): ablation over the variance threshold tau.
//!
//! Reproduction claim: any tau << 1 gives a near-exact final loss/acc;
//! FLOPs reduction grows (mildly) with tau — robustness, not a cliff.

mod common;

use vcas::config::Method;

fn main() {
    let engine = common::load_backend();
    let steps = common::bench_steps(200);
    let taus = [0.0, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5];
    let mut table = common::Table::new(&["tau", "final loss", "eval acc", "FLOPs red."]);
    let mut rows = Vec::new();

    for &tau in &taus {
        let (method, label) = if tau == 0.0 {
            (Method::Exact, "0 (exact)".to_string())
        } else {
            (Method::Vcas, format!("{tau}"))
        };
        let mut cfg = common::base_config("tiny", "sst2-sim", method, steps, 6);
        cfg.vcas.tau_act = tau;
        cfg.vcas.tau_w = tau;
        let r = common::run(&engine, &cfg);
        table.row(vec![
            label.clone(),
            common::f4(r.final_train_loss),
            common::pct(r.final_eval_acc),
            common::pct(r.flops_reduction),
        ]);
        rows.push((
            "sst2-sim".to_string(),
            format!("tau={label}"),
            r.final_train_loss,
            r.final_eval_acc,
            r.flops_reduction,
            r.wall_s,
        ));
    }
    table.print(&format!("Tables 4/5 — tau ablation on sst2-sim ({steps} steps)"));
    common::write_summary_csv("ablation_tau", &rows);
}
