//! §Perf micro-benchmarks: per-entry execute latency, marshalling cost,
//! controller update cost, allreduce cost, and the kernel layer's
//! single- vs multi-thread scaling — the L3 hot-path profile. The kernel
//! section also writes `results/BENCH_kernels.json` so the repo's perf
//! trajectory has machine-readable data points.
//!
//! Run: cargo bench --bench perf_micro

mod common;

use std::collections::BTreeMap;
use std::time::Instant;

use vcas::coordinator::parallel::tree_allreduce_mean;
use vcas::coordinator::vcas::{GradSample, VcasController};
use vcas::config::VcasConfig;
use vcas::data::batch::{gather_cls, EpochSampler};
use vcas::data::tasks::{find, generate_cls};
use vcas::formats::json::Json;
use vcas::runtime::kernels::{reference, Layout, MatmulPlan};
use vcas::runtime::{Backend, ModelSession, NativeBackend};
use vcas::util::rng::Pcg32;

fn main() {
    let engine = common::load_backend();
    let mut table = common::Table::new(&["component", "median ms", "notes"]);

    for model in ["tiny", "small"] {
        let sess = ModelSession::open(engine.as_ref(), model).unwrap();
        let params = sess.load_params().unwrap();
        let spec = find("sst2-sim").unwrap();
        let ds = generate_cls(&spec, sess.vocab, sess.seq_len, 256, 1);
        let mut sampler = EpochSampler::new(256, 1);
        let batch = gather_cls(&ds, &sampler.take(engine.main_batch()));
        let sw = vec![1.0 / batch.n as f32; batch.n];
        let ones_l = vec![1.0f32; sess.n_layers];
        let ones_w = vec![1.0f32; sess.n_sampled];
        let rho = vec![0.4f32; sess.n_layers];
        let nu = vec![0.4f32; sess.n_sampled];

        // warmup (XLA backend: compile; native backend: cache warm)
        let t0 = Instant::now();
        sess.fwd_bwd_cls(&params, &batch, &sw, 0, &ones_l, &ones_w, &ones_w)
            .unwrap();
        let compile_ms = t0.elapsed().as_secs_f64() * 1e3;
        table.row(vec![
            format!("{model}: first fwd_bwd (compile+run)"),
            format!("{compile_ms:.1}"),
            "one-time".into(),
        ]);

        let ms = common::time_median_ms(7, || {
            sess.fwd_bwd_cls(&params, &batch, &sw, 1, &ones_l, &ones_w, &ones_w)
                .unwrap();
        });
        table.row(vec![format!("{model}: fwd_bwd exact"), format!("{ms:.1}"), "hot".into()]);

        let ms = common::time_median_ms(7, || {
            sess.fwd_bwd_cls(&params, &batch, &sw, 1, &rho, &nu, &nu).unwrap();
        });
        table.row(vec![format!("{model}: fwd_bwd sampled"), format!("{ms:.1}"), "hot".into()]);

        let ms = common::time_median_ms(7, || {
            sess.fwd_loss_cls(&params, &batch).unwrap();
        });
        table.row(vec![format!("{model}: fwd_loss"), format!("{ms:.1}"), "baselines".into()]);

        let ms = common::time_median_ms(7, || {
            sess.eval_cls(&params, &batch).unwrap();
        });
        table.row(vec![format!("{model}: eval"), format!("{ms:.1}"), String::new()]);

        let ms = common::time_median_ms(15, || {
            let flat = params.flat();
            std::hint::black_box(&flat);
        });
        table.row(vec![
            format!("{model}: param flatten/marshal"),
            format!("{ms:.2}"),
            format!("{} tensors", params.tensors.len()),
        ]);
    }

    // controller update cost at realistic sizes
    {
        let n_tensors = 55;
        let sizes = 10_000;
        let mut rng = Pcg32::new(1, 1);
        let mk = |rng: &mut Pcg32| GradSample {
            grads: (0..n_tensors)
                .map(|_| (0..sizes).map(|_| rng.normal() as f32).collect())
                .collect(),
            act_norms: (0..4 * 32).map(|_| rng.f32()).collect(),
            vw: vec![0.01; 16],
        };
        let exact = vec![mk(&mut rng), mk(&mut rng)];
        let sampled = vec![vec![mk(&mut rng), mk(&mut rng)], vec![mk(&mut rng), mk(&mut rng)]];
        let mut c = VcasController::new(VcasConfig::default(), 4, (0..16).collect(), 32);
        let ms = common::time_median_ms(5, || {
            c.update(0, &exact, &sampled);
        });
        table.row(vec![
            "controller update (M=2, 550k params)".into(),
            format!("{ms:.2}"),
            "per F steps".into(),
        ]);
    }

    // allreduce cost
    {
        let mut rng = Pcg32::new(2, 2);
        let grads: Vec<Vec<Vec<f32>>> = (0..8)
            .map(|_| vec![(0..700_000).map(|_| rng.f32()).collect()])
            .collect();
        let ms = common::time_median_ms(5, || {
            let _ = tree_allreduce_mean(grads.clone()).unwrap();
        });
        table.row(vec![
            "tree allreduce (8 workers, 700k params)".into(),
            format!("{ms:.2}"),
            "incl clone".into(),
        ]);
    }

    // kernel layer: naive loop vs blocked+threaded MatmulPlan, plus the
    // end-to-end fwd_bwd scaling — the acceptance target is >= 2x matmul
    // speedup at 4 threads on 512^3 over the naive reference.
    let mut kernels_json: BTreeMap<String, Json> = BTreeMap::new();
    {
        let (m, k, n) = (512usize, 512, 512);
        let mut rng = Pcg32::new(7, 7);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let naive_ms = common::time_median_ms(5, || {
            std::hint::black_box(reference::matmul(&a, &b, m, k, n));
        });
        table.row(vec![
            format!("matmul {m}x{k}x{n} naive"),
            format!("{naive_ms:.1}"),
            "PR 1 baseline".into(),
        ]);
        let mut mm: BTreeMap<String, Json> = BTreeMap::new();
        mm.insert("naive_ms".into(), Json::Num(naive_ms));
        let mut ms4 = naive_ms;
        for threads in [1usize, 2, 4] {
            let plan = MatmulPlan::with_threads(Layout::Nn, m, k, n, threads);
            let ms = common::time_median_ms(5, || {
                std::hint::black_box(plan.run(&a, &b));
            });
            table.row(vec![
                format!("matmul {m}x{k}x{n} blocked, {threads} thr"),
                format!("{ms:.1}"),
                format!("{:.2}x vs naive", naive_ms / ms),
            ]);
            mm.insert(format!("threads_{threads}_ms"), Json::Num(ms));
            if threads == 4 {
                ms4 = ms;
            }
        }
        mm.insert("speedup_4t_vs_naive".into(), Json::Num(naive_ms / ms4));
        kernels_json.insert("matmul_512".into(), Json::Obj(mm));
    }
    {
        // fwd_bwd on "small" at 1 vs 4 kernel threads (bitwise-identical
        // results; only wall-clock moves)
        let spec = find("sst2-sim").unwrap();
        let mut fb: BTreeMap<String, Json> = BTreeMap::new();
        let mut ms_by_threads = [0.0f64; 2];
        for (slot, threads) in [1usize, 4].into_iter().enumerate() {
            let nb = NativeBackend::with_default_models().with_threads(threads);
            let sess = ModelSession::open(&nb, "small").unwrap();
            let params = sess.load_params().unwrap();
            let ds = generate_cls(&spec, sess.vocab, sess.seq_len, 256, 1);
            let mut sampler = EpochSampler::new(256, 1);
            let batch = gather_cls(&ds, &sampler.take(nb.main_batch()));
            let sw = vec![1.0 / batch.n as f32; batch.n];
            let ones_l = vec![1.0f32; sess.n_layers];
            let ones_w = vec![1.0f32; sess.n_sampled];
            let ms = common::time_median_ms(7, || {
                sess.fwd_bwd_cls(&params, &batch, &sw, 1, &ones_l, &ones_w, &ones_w)
                    .unwrap();
            });
            table.row(vec![
                format!("small: fwd_bwd exact, {threads} thr"),
                format!("{ms:.1}"),
                "kernel scaling".into(),
            ]);
            fb.insert(format!("threads_{threads}_ms"), Json::Num(ms));
            ms_by_threads[slot] = ms;
        }
        fb.insert("speedup_4t".into(), Json::Num(ms_by_threads[0] / ms_by_threads[1]));
        kernels_json.insert("fwd_bwd_small".into(), Json::Obj(fb));
    }
    let json_path = common::results_dir().join("BENCH_kernels.json");
    std::fs::write(&json_path, format!("{}\n", Json::Obj(kernels_json))).unwrap();
    println!("(kernel scaling json: {})", json_path.display());

    table.print("perf_micro — L3 hot-path profile");
}
