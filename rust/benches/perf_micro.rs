//! §Perf micro-benchmarks: per-entry execute latency, marshalling cost,
//! controller update cost, allreduce cost, the kernel layer's single- vs
//! multi-thread scaling, the zero-scan vs gather-compacted sampled
//! backward across keep ratios, the sync-vs-prefetch step time of the
//! async batch pipeline, sequential vs overlapped DDP reduction at
//! 2/4/8 workers, the reduced-precision tiers (f32 vs bf16 kernels,
//! f32 vs int8 serving), and the sampler-strategy layer (per-strategy
//! step time + estimator variance, the approx-VJP vjp_rho sweep, and a
//! same-seed vcas vs approx_vjp trajectory comparison), plus the
//! telemetry registry's overhead on the threaded matmul hot path — the
//! L3 hot-path profile. The kernel section
//! writes `results/BENCH_kernels.json`, the sampling section
//! `results/BENCH_sampling.json`, the pipeline section
//! `results/BENCH_pipeline.json` and the serving section (p50/p99 latency
//! vs offered load vs max batch size under the open-loop generator)
//! `results/BENCH_serving.json` so the repo's perf trajectory has
//! machine-readable data points.
//!
//! Run: cargo bench --bench perf_micro

mod common;

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use vcas::coordinator::parallel::tree_allreduce_mean;
use vcas::coordinator::pipeline::{MlmSource, Prefetcher};
use vcas::coordinator::vcas::{GradSample, VcasController};
use vcas::coordinator::Trainer;
use vcas::config::{Method, TrainConfig, VcasConfig};
use vcas::data::batch::{gather_cls, EpochSampler};
use vcas::data::tasks::{find, generate_cls, MarkovCorpus};
use vcas::formats::json::Json;
use vcas::runtime::kernels::{reference, weighted_gather_tn, Layout, MatmulPlan, Workspace};
use vcas::runtime::native::sampling::SampledRows;
use vcas::runtime::{Backend, KernelCtx, ModelSession, NativeBackend, Precision, TransformerCfg};
use vcas::sampling::SamplerStrategy;
use vcas::util::rng::Pcg32;

fn main() {
    let engine = common::load_backend();
    let mut table = common::Table::new(&["component", "median ms", "notes"]);

    for model in ["tiny", "small"] {
        let sess = ModelSession::open(engine.as_ref(), model).unwrap();
        let params = sess.load_params().unwrap();
        let spec = find("sst2-sim").unwrap();
        let ds = generate_cls(&spec, sess.vocab, sess.seq_len, 256, 1);
        let mut sampler = EpochSampler::new(256, 1);
        let batch = gather_cls(&ds, &sampler.take(engine.main_batch()));
        let sw = vec![1.0 / batch.n as f32; batch.n];
        let ones_l = vec![1.0f32; sess.n_layers];
        let ones_w = vec![1.0f32; sess.n_sampled];
        let rho = vec![0.4f32; sess.n_layers];
        let nu = vec![0.4f32; sess.n_sampled];

        // warmup (XLA backend: compile; native backend: cache warm)
        let t0 = Instant::now();
        sess.fwd_bwd_cls(&params, &batch, &sw, 0, &ones_l, &ones_w, &ones_w)
            .unwrap();
        let compile_ms = t0.elapsed().as_secs_f64() * 1e3;
        table.row(vec![
            format!("{model}: first fwd_bwd (compile+run)"),
            format!("{compile_ms:.1}"),
            "one-time".into(),
        ]);

        let ms = common::time_median_ms(7, || {
            sess.fwd_bwd_cls(&params, &batch, &sw, 1, &ones_l, &ones_w, &ones_w)
                .unwrap();
        });
        table.row(vec![format!("{model}: fwd_bwd exact"), format!("{ms:.1}"), "hot".into()]);

        let ms = common::time_median_ms(7, || {
            sess.fwd_bwd_cls(&params, &batch, &sw, 1, &rho, &nu, &nu).unwrap();
        });
        table.row(vec![format!("{model}: fwd_bwd sampled"), format!("{ms:.1}"), "hot".into()]);

        let ms = common::time_median_ms(7, || {
            sess.fwd_loss_cls(&params, &batch).unwrap();
        });
        table.row(vec![format!("{model}: fwd_loss"), format!("{ms:.1}"), "baselines".into()]);

        let ms = common::time_median_ms(7, || {
            sess.eval_cls(&params, &batch).unwrap();
        });
        table.row(vec![format!("{model}: eval"), format!("{ms:.1}"), String::new()]);

        let ms = common::time_median_ms(15, || {
            let flat = params.flat();
            std::hint::black_box(&flat);
        });
        table.row(vec![
            format!("{model}: param flatten/marshal"),
            format!("{ms:.2}"),
            format!("{} tensors", params.tensors.len()),
        ]);
    }

    // controller update cost at realistic sizes
    {
        let n_tensors = 55;
        let sizes = 10_000;
        let mut rng = Pcg32::new(1, 1);
        let mk = |rng: &mut Pcg32| GradSample {
            grads: (0..n_tensors)
                .map(|_| (0..sizes).map(|_| rng.normal() as f32).collect())
                .collect(),
            act_norms: (0..4 * 32).map(|_| rng.f32()).collect(),
            vw: vec![0.01; 16],
        };
        let exact = vec![mk(&mut rng), mk(&mut rng)];
        let sampled = vec![vec![mk(&mut rng), mk(&mut rng)], vec![mk(&mut rng), mk(&mut rng)]];
        let mut c = VcasController::new(VcasConfig::default(), 4, (0..16).collect(), 32);
        let ms = common::time_median_ms(5, || {
            c.update(0, &exact, &sampled);
        });
        table.row(vec![
            "controller update (M=2, 550k params)".into(),
            format!("{ms:.2}"),
            "per F steps".into(),
        ]);
    }

    // allreduce cost
    {
        let mut rng = Pcg32::new(2, 2);
        let grads: Vec<Vec<Vec<f32>>> = (0..8)
            .map(|_| vec![(0..700_000).map(|_| rng.f32()).collect()])
            .collect();
        let ms = common::time_median_ms(5, || {
            let _ = tree_allreduce_mean(grads.clone()).unwrap();
        });
        table.row(vec![
            "tree allreduce (8 workers, 700k params)".into(),
            format!("{ms:.2}"),
            "incl clone".into(),
        ]);
    }

    // kernel layer: naive loop vs the PR 2 blocked tiles vs the PR 4 SIMD
    // microkernels (bitwise-identical results across all three), plus the
    // end-to-end fwd_bwd scaling. Acceptance targets: >= 2x matmul speedup
    // at 4 threads over the naive reference, and the SIMD tier beating the
    // blocked tiles wall-clock on every large-shape row.
    let mut kernels_json: BTreeMap<String, Json> = BTreeMap::new();
    {
        let (m, k, n) = (512usize, 512, 512);
        let mut rng = Pcg32::new(7, 7);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let naive_ms = common::time_median_ms(5, || {
            std::hint::black_box(reference::matmul(&a, &b, m, k, n));
        });
        table.row(vec![
            format!("matmul {m}x{k}x{n} naive"),
            format!("{naive_ms:.1}"),
            "PR 1 baseline".into(),
        ]);
        let mut mm: BTreeMap<String, Json> = BTreeMap::new();
        mm.insert("naive_ms".into(), Json::Num(naive_ms));
        let (mut blocked1, mut blocked4, mut simd1, mut simd4) =
            (naive_ms, naive_ms, naive_ms, naive_ms);
        for threads in [1usize, 2, 4] {
            let blocked = MatmulPlan::with_threads(Layout::Nn, m, k, n, threads)
                .with_simd(false);
            let bms = common::time_median_ms(5, || {
                std::hint::black_box(blocked.run(&a, &b));
            });
            table.row(vec![
                format!("matmul {m}x{k}x{n} blocked, {threads} thr"),
                format!("{bms:.1}"),
                format!("{:.2}x vs naive", naive_ms / bms),
            ]);
            mm.insert(format!("blocked_threads_{threads}_ms"), Json::Num(bms));
            let vect = MatmulPlan::with_threads(Layout::Nn, m, k, n, threads).with_simd(true);
            let sms = common::time_median_ms(5, || {
                std::hint::black_box(vect.run(&a, &b));
            });
            table.row(vec![
                format!("matmul {m}x{k}x{n} SIMD, {threads} thr"),
                format!("{sms:.1}"),
                format!("{:.2}x vs blocked", bms / sms),
            ]);
            mm.insert(format!("simd_threads_{threads}_ms"), Json::Num(sms));
            if threads == 1 {
                blocked1 = bms;
                simd1 = sms;
            }
            if threads == 4 {
                blocked4 = bms;
                simd4 = sms;
            }
        }
        // tier-qualified keys: the PR 2 "speedup_4t_vs_naive" series ends
        // here; longitudinal readers get each tier under its own name
        mm.insert("blocked_speedup_4t_vs_naive".into(), Json::Num(naive_ms / blocked4));
        mm.insert("simd_speedup_4t_vs_naive".into(), Json::Num(naive_ms / simd4));
        mm.insert("simd_speedup_vs_blocked_1t".into(), Json::Num(blocked1 / simd1));
        kernels_json.insert("matmul_512".into(), Json::Obj(mm));

        // NT and TN at the same large shape, 1 thread: the layouts the
        // sampled backward actually runs (gz = g @ W^T, gw = z^T diag(m) g)
        let bt: Vec<f32> = (0..n * k).map(|_| rng.normal() as f32).collect();
        for (label, layout) in [("nt", Layout::Nt), ("tn", Layout::Tn)] {
            let (lhs, rhs): (&[f32], &[f32]) = match layout {
                Layout::Nt => (&a, &bt),
                _ => (&a, &b),
            };
            let run = |plan: MatmulPlan| match layout {
                Layout::Tn => plan.run_weighted(lhs, rhs, None),
                _ => plan.run(lhs, rhs),
            };
            let blocked = MatmulPlan::with_threads(layout, m, k, n, 1).with_simd(false);
            let bms = common::time_median_ms(5, || {
                std::hint::black_box(run(blocked));
            });
            let vect = blocked.with_simd(true);
            let sms = common::time_median_ms(5, || {
                std::hint::black_box(run(vect));
            });
            table.row(vec![
                format!("matmul {m}^3 {} SIMD, 1 thr", label.to_uppercase()),
                format!("{sms:.1}"),
                format!("blocked {bms:.1} ms, {:.2}x", bms / sms),
            ]);
            let mut o: BTreeMap<String, Json> = BTreeMap::new();
            o.insert("blocked_ms".into(), Json::Num(bms));
            o.insert("simd_ms".into(), Json::Num(sms));
            o.insert("simd_speedup_vs_blocked".into(), Json::Num(bms / sms));
            kernels_json.insert(format!("matmul_512_{label}"), Json::Obj(o));
        }
    }
    {
        // fwd_bwd on "small": kernel-thread scaling and scalar-vs-SIMD tier
        // (bitwise-identical results; only wall-clock moves)
        let spec = find("sst2-sim").unwrap();
        let mut fb: BTreeMap<String, Json> = BTreeMap::new();
        let mut ms_of = BTreeMap::new();
        for (threads, simd) in [(1usize, false), (1, true), (4, false), (4, true)] {
            let nb = NativeBackend::with_default_models()
                .with_threads(threads)
                .with_simd(simd);
            let sess = ModelSession::open(&nb, "small").unwrap();
            let params = sess.load_params().unwrap();
            let ds = generate_cls(&spec, sess.vocab, sess.seq_len, 256, 1);
            let mut sampler = EpochSampler::new(256, 1);
            let batch = gather_cls(&ds, &sampler.take(nb.main_batch()));
            let sw = vec![1.0 / batch.n as f32; batch.n];
            let ones_l = vec![1.0f32; sess.n_layers];
            let ones_w = vec![1.0f32; sess.n_sampled];
            let ms = common::time_median_ms(7, || {
                sess.fwd_bwd_cls(&params, &batch, &sw, 1, &ones_l, &ones_w, &ones_w)
                    .unwrap();
            });
            let tier = if simd { "simd" } else { "scalar" };
            table.row(vec![
                format!("small: fwd_bwd exact, {threads} thr, {tier}"),
                format!("{ms:.1}"),
                "kernel scaling".into(),
            ]);
            fb.insert(format!("threads_{threads}_{tier}_ms"), Json::Num(ms));
            ms_of.insert((threads, simd), ms);
        }
        // tier-qualified: PR 2's "speedup_4t" measured the scalar tier
        fb.insert(
            "scalar_speedup_4t".into(),
            Json::Num(ms_of[&(1, false)] / ms_of[&(4, false)]),
        );
        fb.insert(
            "simd_tier_speedup_4t".into(),
            Json::Num(ms_of[&(1, true)] / ms_of[&(4, true)]),
        );
        fb.insert(
            "simd_speedup_1t".into(),
            Json::Num(ms_of[&(1, false)] / ms_of[&(1, true)]),
        );
        fb.insert(
            "simd_speedup_4t".into(),
            Json::Num(ms_of[&(4, false)] / ms_of[&(4, true)]),
        );
        kernels_json.insert("fwd_bwd_small".into(), Json::Obj(fb));
    }
    // precision tiers: f32 vs bf16 on the matmul and fwd_bwd hot paths.
    // bf16 packs both operands to u16 before the tile loop, halving the
    // bytes the inner loops stream at the cost of a pack pass — both the
    // wall-clock (pack included) and the analytic operand traffic land in
    // the json so the bytes-moved claim is checkable against the timing.
    {
        let (m, k, n) = (512usize, 512, 512);
        let mut rng = Pcg32::new(13, 13);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let mut o: BTreeMap<String, Json> = BTreeMap::new();
        for threads in [1usize, 4] {
            let f32_plan = MatmulPlan::with_threads(Layout::Nn, m, k, n, threads);
            let fms = common::time_median_ms(5, || {
                std::hint::black_box(f32_plan.run(&a, &b));
            });
            let bf16_plan = f32_plan.with_precision(Precision::Bf16);
            let bms = common::time_median_ms(5, || {
                std::hint::black_box(bf16_plan.run(&a, &b));
            });
            table.row(vec![
                format!("matmul {m}x{k}x{n} bf16, {threads} thr"),
                format!("{bms:.1}"),
                format!("f32 {fms:.1} ms, {:.2}x", fms / bms),
            ]);
            o.insert(format!("f32_threads_{threads}_ms"), Json::Num(fms));
            o.insert(format!("bf16_threads_{threads}_ms"), Json::Num(bms));
        }
        let f32_bytes = ((m * k + k * n) * 4) as f64;
        o.insert("operand_bytes_f32".into(), Json::Num(f32_bytes));
        o.insert("operand_bytes_bf16".into(), Json::Num(f32_bytes / 2.0));
        kernels_json.insert("precision_matmul_512".into(), Json::Obj(o));
    }
    {
        // end-to-end tier cost: "small" exact fwd_bwd, f32 vs bf16 backend
        let spec = find("sst2-sim").unwrap();
        let mut o: BTreeMap<String, Json> = BTreeMap::new();
        let mut tier_ms = [0.0f64; 2];
        for (slot, (tier, precision)) in
            [("f32", Precision::F32), ("bf16", Precision::Bf16)].into_iter().enumerate()
        {
            let nb = NativeBackend::with_default_models()
                .with_threads(4)
                .with_precision(precision);
            let sess = ModelSession::open(&nb, "small").unwrap();
            let params = sess.load_params().unwrap();
            let ds = generate_cls(&spec, sess.vocab, sess.seq_len, 256, 1);
            let mut sampler = EpochSampler::new(256, 1);
            let batch = gather_cls(&ds, &sampler.take(nb.main_batch()));
            let sw = vec![1.0 / batch.n as f32; batch.n];
            let ones_l = vec![1.0f32; sess.n_layers];
            let ones_w = vec![1.0f32; sess.n_sampled];
            // warm the workspace (bf16 additionally warms the u16 pool)
            sess.fwd_bwd_cls(&params, &batch, &sw, 0, &ones_l, &ones_w, &ones_w).unwrap();
            let ms = common::time_median_ms(7, || {
                sess.fwd_bwd_cls(&params, &batch, &sw, 1, &ones_l, &ones_w, &ones_w)
                    .unwrap();
            });
            table.row(vec![
                format!("small: fwd_bwd exact, 4 thr, {tier}"),
                format!("{ms:.1}"),
                "precision tier".into(),
            ]);
            o.insert(format!("{tier}_ms"), Json::Num(ms));
            tier_ms[slot] = ms;
        }
        o.insert("bf16_speedup".into(), Json::Num(tier_ms[0] / tier_ms[1]));
        kernels_json.insert("precision_fwd_bwd_small".into(), Json::Obj(o));
    }
    // telemetry registry overhead on the kernel hot path: the same
    // threaded matmul with and without the per-call bookkeeping the
    // runtime does when metrics are live (one relaxed counter inc + one
    // histogram observe per call). Acceptance: <= 2% overhead, recorded
    // as telemetry_overhead_pct so the claim stays checkable.
    {
        use vcas::telemetry::Registry;
        let (m, k, n) = (256usize, 256, 256);
        let mut rng = Pcg32::new(23, 23);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let plan = MatmulPlan::with_threads(Layout::Nn, m, k, n, 4);
        let reps = 8usize;
        let bare_ms = common::time_median_ms(7, || {
            for _ in 0..reps {
                std::hint::black_box(plan.run(&a, &b));
            }
        });
        let registry = Registry::new();
        let calls = registry.counter("bench_matmul_calls");
        let lat = registry.histogram("bench_matmul_us");
        let metered_ms = common::time_median_ms(7, || {
            for i in 0..reps {
                std::hint::black_box(plan.run(&a, &b));
                calls.inc();
                lat.observe((i + 1) as f64);
            }
        });
        let overhead_pct = (metered_ms / bare_ms - 1.0) * 100.0;
        table.row(vec![
            format!("matmul {m}^3 + registry write, 4 thr"),
            format!("{metered_ms:.2}"),
            format!("bare {bare_ms:.2} ms, overhead {overhead_pct:+.2}%"),
        ]);
        let mut o: BTreeMap<String, Json> = BTreeMap::new();
        o.insert("bare_ms".into(), Json::Num(bare_ms));
        o.insert("metered_ms".into(), Json::Num(metered_ms));
        o.insert("telemetry_overhead_pct".into(), Json::Num(overhead_pct));
        kernels_json.insert("telemetry_matmul_256".into(), Json::Obj(o));
    }
    let json_path = common::results_dir().join("BENCH_kernels.json");
    std::fs::write(&json_path, format!("{}\n", Json::Obj(kernels_json))).unwrap();
    println!("(kernel scaling json: {})", json_path.display());

    // compacted sampled execution: zero-scan vs gather/scatter backward
    // rows across keep ratios. The "backward rows" composite is the two
    // contractions a sampled linear's backward runs over the row-sampled
    // gradient: gz = g @ W^T (NT) and gw = z^T diag(m) g (TN). The
    // acceptance target is compacted wall-clock decreasing monotonically
    // with the keep ratio and >= 2x over zero-scan at ratio 0.25.
    let mut sampling_json: BTreeMap<String, Json> = BTreeMap::new();
    {
        let (rows, dout, din) = (1024usize, 192, 192);
        let threads = 4usize;
        let ctx = KernelCtx::new(threads);
        let ws = Workspace::new();
        let mut rng = Pcg32::new(11, 11);
        let gdense: Vec<f32> = (0..rows * dout).map(|_| rng.normal() as f32).collect();
        let z: Vec<f32> = (0..rows * din).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..din * dout).map(|_| rng.normal() as f32).collect();
        let nt = MatmulPlan::with_threads(Layout::Nt, rows, dout, din, threads);
        let tn = MatmulPlan::with_threads(Layout::Tn, din, rows, dout, threads);
        for ratio in [0.1f64, 0.25, 0.5, 0.75, 1.0] {
            let mut mask_rng = Pcg32::new(5, 5);
            let sr = SampledRows::sample(&gdense, dout, ratio as f32, &mut mask_rng).unwrap();
            let mut zeroed = gdense.clone();
            sr.apply(&mut zeroed, dout);
            // full-length weight vector for the zero-scan TN row scan
            let mut wfull = vec![0.0f32; rows];
            for (&i, &s) in sr.kept.iter().zip(&sr.scales) {
                wfull[i as usize] = s;
            }
            let zero_ms = common::time_median_ms(5, || {
                std::hint::black_box(nt.run(&zeroed, &w));
                std::hint::black_box(tn.run_weighted(&z, &zeroed, Some(&wfull)));
            });
            let mut gz = vec![0.0f32; rows * din];
            let compact_ms = common::time_median_ms(5, || {
                nt.run_gather_nt(&ws, &gdense, &w, &sr.kept, &sr.scales, &mut gz);
                std::hint::black_box(&gz);
                std::hint::black_box(weighted_gather_tn(
                    ctx, &z, &zeroed, &sr.kept, &sr.scales, din, dout,
                ));
            });
            table.row(vec![
                format!("sampled bwd rows {rows}x{dout} keep {ratio}"),
                format!("{compact_ms:.2}"),
                format!(
                    "zero-scan {zero_ms:.2} ms, {:.2}x, {} rows kept",
                    zero_ms / compact_ms,
                    sr.n_kept()
                ),
            ]);
            let mut o: BTreeMap<String, Json> = BTreeMap::new();
            o.insert("kept_rows".into(), Json::Num(sr.n_kept() as f64));
            o.insert("zero_scan_ms".into(), Json::Num(zero_ms));
            o.insert("compact_ms".into(), Json::Num(compact_ms));
            o.insert("speedup".into(), Json::Num(zero_ms / compact_ms));
            sampling_json.insert(format!("kernel_bwd_rows_ratio_{ratio}"), Json::Obj(o));
        }
    }
    {
        // end-to-end: "small" sampled fwd_bwd at rho = nu = 0.25, zero-scan
        // vs compacted backend (bitwise-identical results, wall-clock only)
        let spec = find("sst2-sim").unwrap();
        let mut e2e: BTreeMap<String, Json> = BTreeMap::new();
        let mut ms_by_mode = [0.0f64; 2];
        for (slot, (mode, compact)) in
            [("zero_scan", false), ("compacted", true)].into_iter().enumerate()
        {
            let nb = NativeBackend::with_default_models()
                .with_threads(1)
                .with_compaction(compact);
            let sess = ModelSession::open(&nb, "small").unwrap();
            let params = sess.load_params().unwrap();
            let ds = generate_cls(&spec, sess.vocab, sess.seq_len, 256, 1);
            let mut sampler = EpochSampler::new(256, 1);
            let batch = gather_cls(&ds, &sampler.take(nb.main_batch()));
            let sw = vec![1.0 / batch.n as f32; batch.n];
            let rho = vec![0.25f32; sess.n_layers];
            let nu = vec![0.25f32; sess.n_sampled];
            // warm the workspace so steady-state timing excludes first
            // allocations
            sess.fwd_bwd_cls(&params, &batch, &sw, 1, &rho, &nu, &nu).unwrap();
            let ms = common::time_median_ms(7, || {
                sess.fwd_bwd_cls(&params, &batch, &sw, 1, &rho, &nu, &nu).unwrap();
            });
            table.row(vec![
                format!("small: fwd_bwd rho 0.25, {mode}"),
                format!("{ms:.1}"),
                "compaction".into(),
            ]);
            e2e.insert(format!("{mode}_ms"), Json::Num(ms));
            ms_by_mode[slot] = ms;
        }
        e2e.insert("speedup".into(), Json::Num(ms_by_mode[0] / ms_by_mode[1]));
        sampling_json.insert("fwd_bwd_small_rho_0.25".into(), Json::Obj(e2e));
    }
    // strategy layer: per-strategy trainer step time plus the empirical
    // estimator variance each SamplerStrategy trades for its FLOPs saving
    // (Fig. 5-style v_extra on a fixed batch, v_sgd across batches). The
    // approx-VJP family is swept over vjp_rho — its keep-ratio knob — so
    // the variance/ratio curve of the sketch sits next to the kernel-level
    // keep-ratio rows above.
    {
        let chunk = (common::bench_steps(24) / 3).max(2);
        let nb = NativeBackend::with_default_models();
        for (name, method, vjp_rho) in [
            ("exact", Method::Exact, 1.0f64),
            ("vcas", Method::Vcas, 1.0),
            ("sb", Method::Sb, 1.0),
            ("ub", Method::Ub, 1.0),
            ("uniform", Method::Uniform, 1.0),
            ("approx_vjp_rho_0.25", Method::ApproxVjp, 0.25),
            ("approx_vjp_rho_0.5", Method::ApproxVjp, 0.5),
            ("approx_vjp_rho_0.75", Method::ApproxVjp, 0.75),
        ] {
            let mut cfg = TrainConfig {
                model: "tiny".into(),
                task: "sst2-sim".into(),
                method: method.clone(),
                steps: 2 + 3 * chunk,
                seed: 17,
                prefetch: Some(0),
                vcas: VcasConfig { freq: 8, ..Default::default() },
                ..Default::default()
            };
            cfg.strategy.vjp_rho = vjp_rho;
            let mut tr = Trainer::new(&nb, &cfg).unwrap();
            // warm-up: workspace pool and (for vcas) the first probe
            tr.advance(2).unwrap();
            let ms = common::time_median_ms(3, || {
                tr.advance(chunk).unwrap();
            }) / chunk as f64;
            let snap = tr.measure_variance(4).unwrap();
            table.row(vec![
                format!("strategy {name}: trainer step"),
                format!("{ms:.2}"),
                format!("v_extra {:.3e} (v_sgd {:.3e})", snap.v_extra, snap.v_sgd),
            ]);
            let mut o: BTreeMap<String, Json> = BTreeMap::new();
            o.insert("step_ms".into(), Json::Num(ms));
            o.insert("v_sgd".into(), Json::Num(snap.v_sgd));
            o.insert("v_extra".into(), Json::Num(snap.v_extra));
            if method == Method::ApproxVjp {
                o.insert("vjp_rho".into(), Json::Num(vjp_rho));
                let trace = tr.strategy().variance_trace();
                let mean = trace.iter().map(|&(_, v)| v as f64).sum::<f64>()
                    / trace.len().max(1) as f64;
                o.insert("sketch_var_mean".into(), Json::Num(mean));
            }
            sampling_json.insert(format!("strategy_{name}"), Json::Obj(o));
        }
    }
    // same-seed vcas vs approx_vjp: identical batch sequence and seed, so
    // final loss / FLOPs reduction / estimator variance compare the two
    // adaptive families head to head on one trajectory pair.
    {
        let steps = common::bench_steps(24);
        let nb = NativeBackend::with_default_models();
        let mk = |method: Method, vjp_rho: f64| {
            let mut cfg = TrainConfig {
                model: "tiny".into(),
                task: "sst2-sim".into(),
                method,
                steps,
                seed: 17,
                prefetch: Some(0),
                vcas: VcasConfig { freq: 8, ..Default::default() },
                ..Default::default()
            };
            cfg.strategy.vjp_rho = vjp_rho;
            cfg
        };
        let mut o: BTreeMap<String, Json> = BTreeMap::new();
        o.insert("seed".into(), Json::Num(17.0));
        o.insert("steps".into(), Json::Num(steps as f64));
        for (name, method) in [("vcas", Method::Vcas), ("approx_vjp", Method::ApproxVjp)] {
            let mut tr = Trainer::new(&nb, &mk(method, 0.5)).unwrap();
            let r = tr.run().unwrap();
            let snap = tr.measure_variance(4).unwrap();
            table.row(vec![
                format!("strategy cmp {name} (seed 17)"),
                format!("{:.2}", r.wall_s * 1e3 / steps as f64),
                format!(
                    "final loss {:.4}, flops -{:.1}%, v_extra {:.3e}",
                    r.final_train_loss,
                    r.flops_reduction * 100.0,
                    snap.v_extra
                ),
            ]);
            o.insert(format!("{name}_final_loss"), Json::Num(r.final_train_loss));
            o.insert(format!("{name}_flops_reduction"), Json::Num(r.flops_reduction));
            o.insert(format!("{name}_v_extra"), Json::Num(snap.v_extra));
            o.insert(format!("{name}_v_sgd"), Json::Num(snap.v_sgd));
        }
        sampling_json.insert("strategy_cmp_vcas_vs_approx_vjp".into(), Json::Obj(o));
    }
    let json_path = common::results_dir().join("BENCH_sampling.json");
    std::fs::write(&json_path, format!("{}\n", Json::Obj(sampling_json))).unwrap();
    println!("(compacted sampling json: {})", json_path.display());

    // async training pipeline: synchronous (depth 0) vs double-buffered
    // prefetch (depth 2) on identical batch sequences — trajectories are
    // bitwise equal, so wall-clock is the only thing that can move. Two
    // consumers: the trainer's steady-state step (epoch shuffle + gather
    // on the producer thread) and an MLM session loop, where per-batch
    // mask generation is real host-side work worth overlapping. The
    // acceptance target is prefetch >= break-even on steady-state step
    // time. Rows land in results/BENCH_pipeline.json, which CI uploads
    // with the other BENCH_*.json artifacts.
    let mut pipeline_json: BTreeMap<String, Json> = BTreeMap::new();
    {
        let steps = 6usize;
        let mut step_ms = [0.0f64; 2];
        for (slot, depth) in [0usize, 2].into_iter().enumerate() {
            let cfg = TrainConfig {
                model: "small".into(),
                task: "sst2-sim".into(),
                method: Method::Vcas,
                steps: 64,
                seed: 5,
                prefetch: Some(depth),
                vcas: VcasConfig { freq: 50, ..Default::default() },
                ..Default::default()
            };
            let nb = NativeBackend::with_default_models();
            let mut tr = Trainer::new(&nb, &cfg).unwrap();
            // warm-up: fill the workspace pool and the prefetch queue
            tr.advance(2).unwrap();
            let ms = common::time_median_ms(5, || {
                tr.advance(steps).unwrap();
            }) / steps as f64;
            let mode = if depth == 0 { "sync" } else { "prefetch" };
            table.row(vec![
                format!("small: trainer step, {mode} (depth {depth})"),
                format!("{ms:.2}"),
                "pipeline".into(),
            ]);
            step_ms[slot] = ms;
        }
        let mut o: BTreeMap<String, Json> = BTreeMap::new();
        o.insert("sync_ms".into(), Json::Num(step_ms[0]));
        o.insert("prefetch_ms".into(), Json::Num(step_ms[1]));
        o.insert("depth".into(), Json::Num(2.0));
        o.insert("speedup".into(), Json::Num(step_ms[0] / step_ms[1]));
        pipeline_json.insert("trainer_step_small_sst2".into(), Json::Obj(o));
    }
    {
        let nb = NativeBackend::with_default_models();
        let sess = ModelSession::open(&nb, "small").unwrap();
        let params = sess.load_params().unwrap();
        let corpus = Arc::new(MarkovCorpus::new(sess.vocab, 0.4, 3));
        let n = nb.main_batch();
        let ones_l = vec![1.0f32; sess.n_layers];
        let ones_w = vec![1.0f32; sess.n_sampled];
        let mut step_ms = [0.0f64; 2];
        for (slot, depth) in [0usize, 2].into_iter().enumerate() {
            let mut pf = Prefetcher::new(
                MlmSource::new(corpus.clone(), n, sess.seq_len, sess.vocab, 0.15, 11),
                depth,
            );
            // warm-up step (also lets the producer fill its queue)
            let b = pf.next().unwrap().into_mlm().unwrap();
            sess.fwd_bwd_mlm(&params, &b, 0, &ones_l, &ones_w, &ones_w).unwrap();
            let ms = common::time_median_ms(7, || {
                let b = pf.next().unwrap().into_mlm().unwrap();
                sess.fwd_bwd_mlm(&params, &b, 1, &ones_l, &ones_w, &ones_w).unwrap();
            });
            let mode = if depth == 0 { "sync" } else { "prefetch" };
            table.row(vec![
                format!("small: mlm masked step, {mode} (depth {depth})"),
                format!("{ms:.2}"),
                "pipeline".into(),
            ]);
            step_ms[slot] = ms;
        }
        let mut o: BTreeMap<String, Json> = BTreeMap::new();
        o.insert("sync_ms".into(), Json::Num(step_ms[0]));
        o.insert("prefetch_ms".into(), Json::Num(step_ms[1]));
        o.insert("depth".into(), Json::Num(2.0));
        o.insert("speedup".into(), Json::Num(step_ms[0] / step_ms[1]));
        pipeline_json.insert("mlm_session_step_small".into(), Json::Obj(o));
    }
    // overlapped DDP reduction: sequential (full backward, then serial
    // tree allreduce) vs the comm scheduler reducing buckets while later
    // layers still compute, on a synthetic layered backward at 2/4/8
    // workers. Results are bitwise identical; the acceptance target is
    // overlap reducing per-round wall-clock at >= 4 workers.
    {
        use vcas::coordinator::comm::{BucketPlan, ReduceOptions, DEFAULT_BUCKET_BYTES};
        use vcas::coordinator::parallel::{data_parallel_grads, data_parallel_grads_overlapped};

        let n_tensors = 12usize;
        let len = 96 * 1024usize; // ~4.5 MB of gradients per worker
        let lens = vec![len; n_tensors];
        let order: Vec<usize> = (0..n_tensors).rev().collect();
        // simulated layer backward: deterministic per-element work, so the
        // reducer has real compute to hide behind (as in a real backward)
        let make_grad = |w: usize, t: usize| -> Vec<f32> {
            let mut v = Vec::with_capacity(len);
            let mut x = (w * 31 + t * 7 + 1) as f32;
            for _ in 0..len {
                x = x * 0.999_9 + 0.017;
                v.push(x);
            }
            v
        };
        for workers in [2usize, 4, 8] {
            let plan = BucketPlan::new(&lens, &order, DEFAULT_BUCKET_BYTES).unwrap();
            let seq_ms = common::time_median_ms(5, || {
                let out = data_parallel_grads(workers, workers, |w, _| {
                    let mut grads = vec![Vec::new(); n_tensors];
                    for &t in &order {
                        grads[t] = make_grad(w, t);
                    }
                    Ok(grads)
                })
                .unwrap();
                std::hint::black_box(&out);
            });
            let overlap_ms = common::time_median_ms(5, || {
                let opts = ReduceOptions::default();
                let out =
                    data_parallel_grads_overlapped(workers, workers, &plan, &opts, |w, _, p| {
                        for &t in &order {
                            p.publish(t, &make_grad(w, t))?;
                        }
                        Ok(())
                    })
                    .unwrap();
                std::hint::black_box(&out);
            });
            table.row(vec![
                format!("ddp round, {workers} workers, overlapped"),
                format!("{overlap_ms:.2}"),
                format!("sequential {seq_ms:.2} ms, {:.2}x", seq_ms / overlap_ms),
            ]);
            let mut o: BTreeMap<String, Json> = BTreeMap::new();
            o.insert("workers".into(), Json::Num(workers as f64));
            o.insert("grad_elems".into(), Json::Num((n_tensors * len) as f64));
            o.insert("bucket_bytes".into(), Json::Num(DEFAULT_BUCKET_BYTES as f64));
            o.insert("seq_ms".into(), Json::Num(seq_ms));
            o.insert("overlap_ms".into(), Json::Num(overlap_ms));
            o.insert("speedup".into(), Json::Num(seq_ms / overlap_ms));
            pipeline_json.insert(format!("ddp_round_workers_{workers}"), Json::Obj(o));
        }
    }
    let json_path = common::results_dir().join("BENCH_pipeline.json");
    std::fs::write(&json_path, format!("{}\n", Json::Obj(pipeline_json))).unwrap();
    println!("(async pipeline json: {})", json_path.display());

    // serving: p50/p99 latency under the open-loop generator, swept over
    // offered load x max batch size on the tiny model. The open-loop
    // schedule does not self-throttle, so queueing delay and the
    // continuous-batching tradeoff (bigger coalescing windows amortize the
    // forward but add wait) show up honestly in the tail.
    let mut serving_json: BTreeMap<String, Json> = BTreeMap::new();
    {
        use std::time::Duration;
        use vcas::serving::{run_open_loop, LoadSpec, ServeConfig, SessionPool};
        let requests = 48usize;
        for max_batch in [1usize, 4, 16] {
            for rate_hz in [200.0f64, 800.0, 3200.0] {
                let backend =
                    Arc::new(NativeBackend::with_default_models().with_threads(2));
                let cfg = ServeConfig {
                    max_batch,
                    max_wait: Duration::from_micros(200),
                    queue_capacity: 64,
                    workers: 2,
                };
                let pool = SessionPool::builder(backend)
                    .model("tiny")
                    .build(cfg)
                    .unwrap();
                let spec = LoadSpec { requests, rate_hz, seed: 0x10AD };
                let report = run_open_loop(&pool, "tiny", &spec).unwrap();
                table.row(vec![
                    format!("serve tiny: {rate_hz} req/s, max_batch {max_batch}"),
                    format!("{:.2}", report.p50_us() / 1000.0),
                    format!(
                        "p99 {:.2} ms, {}/{} done, {} rejected, batch<= {}",
                        report.p99_us() / 1000.0,
                        report.completed,
                        report.offered,
                        report.rejected,
                        report.max_batched
                    ),
                ]);
                let mut o: BTreeMap<String, Json> = BTreeMap::new();
                o.insert("offered_rps".into(), Json::Num(rate_hz));
                o.insert("max_batch".into(), Json::Num(max_batch as f64));
                o.insert("p50_us".into(), Json::Num(report.p50_us()));
                o.insert("p99_us".into(), Json::Num(report.p99_us()));
                o.insert("throughput_rps".into(), Json::Num(report.throughput_rps()));
                o.insert("completed".into(), Json::Num(report.completed as f64));
                o.insert("rejected".into(), Json::Num(report.rejected as f64));
                o.insert("max_batched".into(), Json::Num(report.max_batched as f64));
                serving_json.insert(
                    format!("tiny_rate_{rate_hz}_max_batch_{max_batch}"),
                    Json::Obj(o),
                );
            }
        }

        // precision tiers at the serving layer: f32 vs int8 weights at
        // max_batch 16 under back-to-back load, on a wider transformer
        // ("mid": d_model 128, d_ff 256) where the dense linears dominate
        // the forward — the regime the int8 tier targets. Identical load
        // and coalescing config; only the kernel tier moves.
        let mut o: BTreeMap<String, Json> = BTreeMap::new();
        let mut p50_by = [0.0f64; 2];
        for (slot, (tier, precision)) in
            [("f32", Precision::F32), ("int8", Precision::Int8Infer)].into_iter().enumerate()
        {
            let mut nb = NativeBackend::new(16, 5, 16)
                .with_threads(2)
                .with_precision(precision);
            nb.add_transformer(
                "mid",
                TransformerCfg {
                    vocab: 256,
                    d_model: 128,
                    n_heads: 4,
                    d_ff: 256,
                    n_layers: 2,
                    seq_len: 32,
                    n_classes: 4,
                },
            );
            let cfg = ServeConfig {
                max_batch: 16,
                max_wait: Duration::from_micros(200),
                queue_capacity: 64,
                workers: 2,
            };
            let pool = SessionPool::builder(Arc::new(nb)).model("mid").build(cfg).unwrap();
            let spec = LoadSpec { requests: 64, rate_hz: 0.0, seed: 0x10AD };
            let report = run_open_loop(&pool, "mid", &spec).unwrap();
            table.row(vec![
                format!("serve mid: back-to-back, max_batch 16, {tier}"),
                format!("{:.2}", report.p50_us() / 1000.0),
                format!(
                    "p99 {:.2} ms, {:.1} req/s, batch<= {}",
                    report.p99_us() / 1000.0,
                    report.throughput_rps(),
                    report.max_batched
                ),
            ]);
            o.insert(format!("{tier}_p50_us"), Json::Num(report.p50_us()));
            o.insert(format!("{tier}_p99_us"), Json::Num(report.p99_us()));
            o.insert(format!("{tier}_throughput_rps"), Json::Num(report.throughput_rps()));
            p50_by[slot] = report.p50_us();
        }
        o.insert("max_batch".into(), Json::Num(16.0));
        o.insert("int8_p50_speedup".into(), Json::Num(p50_by[0] / p50_by[1]));
        serving_json.insert("precision_mid_max_batch_16".into(), Json::Obj(o));
    }
    let json_path = common::results_dir().join("BENCH_serving.json");
    std::fs::write(&json_path, format!("{}\n", Json::Obj(serving_json))).unwrap();
    println!("(serving latency json: {})", json_path.display());

    table.print("perf_micro — L3 hot-path profile");
}
