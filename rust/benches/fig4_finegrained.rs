//! Fig. 4: FLOPs reduction of joint (VCAS) vs activation-only vs
//! weight-only sampling at equal total extra variance.
//!
//! Paper protocol: tau_act = tau_w = 0.025 for joint; tau_act = 0.05 for
//! act-only; tau_w = 0.05 for weight-only — same variance budget overall.
//! Reproduction claim: joint achieves the largest FLOPs reduction.

mod common;

use vcas::config::{Method, VcasConfig};

fn main() {
    let engine = common::load_backend();
    let steps = common::bench_steps(240);
    let mut table =
        common::Table::new(&["mode", "tau_act", "tau_w", "final loss", "FLOPs red.", "steady-state"]);
    let mut rows = Vec::new();

    let modes: [(&str, VcasConfig); 3] = [
        (
            "joint (VCAS)",
            VcasConfig { tau_act: 0.025, tau_w: 0.025, ..Default::default() },
        ),
        (
            "activation-only",
            VcasConfig { tau_act: 0.05, act_only: true, ..Default::default() },
        ),
        (
            "weight-only",
            VcasConfig { tau_w: 0.05, weight_only: true, ..Default::default() },
        ),
    ];

    for (name, vcfg) in modes {
        let mut cfg = common::base_config("tiny", "sst2-sim", Method::Vcas, steps, 4);
        let freq = cfg.vcas.freq;
        cfg.vcas = VcasConfig { freq, ..vcfg };
        let r = common::run(&engine, &cfg);
        table.row(vec![
            name.into(),
            format!("{:.3}", cfg.vcas.tau_act),
            format!("{:.3}", cfg.vcas.tau_w),
            common::f4(r.final_train_loss),
            common::pct(r.flops_reduction),
            common::pct(r.steady_state_reduction()),
        ]);
        rows.push((
            "sst2-sim".to_string(),
            name.to_string(),
            r.final_train_loss,
            r.final_eval_acc,
            r.flops_reduction,
            r.wall_s,
        ));
    }
    table.print(&format!(
        "Fig. 4 — fine-grained joint sampling wins at equal variance ({steps} steps)"
    ));
    common::write_summary_csv("fig4_finegrained", &rows);
}
