//! Table 9 (Appendix F): MLM pretraining loss + downstream finetune
//! performance, exact vs VCAS.
//!
//! Reproduction claim: VCAS's pretrain loss is slightly above exact while
//! the *downstream* finetune accuracy is preserved — the convergence
//! trajectory matters, not the last-digit loss.

mod common;

use vcas::config::Method;
use vcas::coordinator::Trainer;
use vcas::formats::params::ParamSet;
use vcas::runtime::Backend;
use vcas::util::rng::Pcg32;

fn main() {
    let engine = common::load_backend();
    let pre_steps = common::bench_steps(200);
    let ft_steps = pre_steps / 2;
    let mut table = common::Table::new(&[
        "method", "pretrain loss", "FLOPs red.", "qnli-sim acc", "sst2-sim acc", "avg",
    ]);

    for method in [Method::Exact, Method::Vcas] {
        let mut cfg = common::base_config("tiny", "mlm", method.clone(), pre_steps, 21);
        cfg.optim.lr = 6e-4;
        cfg.eval_batches = 4;
        let mut pre = Trainer::new(&engine, &cfg).unwrap();
        let pre_r = pre.run().unwrap();

        let ckpt = common::results_dir().join(format!("table9_{}.bin", method.name()));
        pre.save_checkpoint(&ckpt).unwrap();
        let info = engine.info("tiny").unwrap();

        // downstream finetuning (always VCAS, per the paper's GLUE recipe
        // being independent of the pretraining method)
        let mut accs = Vec::new();
        for task in ["qnli-sim", "sst2-sim"] {
            let ft_cfg = common::base_config("tiny", task, Method::Vcas, ft_steps, 31);
            let mut ft = Trainer::new(&engine, &ft_cfg).unwrap();
            let mut params = ParamSet::load_bin(&ckpt, &info.param_specs).unwrap();
            let mut rng = Pcg32::new(77, 0);
            params.reinit_normal("head_w", 0.02, &mut rng);
            params.reinit_normal("head_b", 0.0, &mut rng);
            ft.set_params(params);
            let r = ft.run().unwrap();
            accs.push(r.final_eval_acc);
        }
        let avg = accs.iter().sum::<f64>() / accs.len() as f64;
        table.row(vec![
            method.name().into(),
            common::f4(pre_r.final_train_loss),
            common::pct(pre_r.flops_reduction),
            common::pct(accs[0]),
            common::pct(accs[1]),
            common::pct(avg),
        ]);
    }
    table.print(&format!(
        "Table 9 — pretrain ({pre_steps} steps) + downstream finetune ({ft_steps} steps)"
    ));
}
