//! Concurrency suite for the serving layer.
//!
//! Everything here runs hermetically on the native backend. The four
//! contracts under test:
//!
//! 1. **Batching equivalence** — a request's logits are bitwise identical
//!    whether it ran alone, coalesced into any batch, on any worker count,
//!    at any kernel thread count (swept below).
//! 2. **FIFO fairness** — with one worker, completion order equals
//!    admission-ticket order exactly, even under concurrent submitters.
//! 3. **Admission control** — a full queue rejects with a typed
//!    `Overloaded`, never by blocking; undrained requests resolve to
//!    `Shutdown` at pool drop.
//! 4. **Shutdown** — dropping the pool joins every worker (no detached
//!    threads: the backend `Arc` strong count returns to 1) and admitted
//!    in-flight requests are still answered.
//! 5. **Int8 tier agreement** — a pool built on an `Int8Infer` backend
//!    quantizes weights once at load and must track the f32 pool within
//!    a logit tolerance (top-1 preserved wherever the margin is
//!    decisive), while staying bitwise batch/thread-invariant within
//!    its own tier (i32 accumulation is exact).

use std::sync::Arc;
use std::time::Duration;

use vcas::data::batch::ClsBatch;
use vcas::runtime::{ModelSession, NativeBackend, Precision};
use vcas::serving::{ServeConfig, ServingError, SessionPool};

/// Deterministic per-request token stream (distinct per request index).
fn tokens_for(i: usize, seq_len: usize, vocab: usize) -> Vec<i32> {
    (0..seq_len).map(|t| ((i * 31 + t * 7 + 3) % vocab) as i32).collect()
}

/// Reference logits for requests 0..n: one batched forward through a
/// plain `ModelSession` on a fresh single-threaded backend.
fn reference_logits(n: usize) -> Vec<Vec<f32>> {
    let backend = NativeBackend::with_default_models().with_threads(1);
    let sess = ModelSession::open(&backend, "tiny").unwrap();
    let params = sess.load_params().unwrap();
    let (seq_len, vocab, n_classes) = (sess.seq_len, sess.vocab, sess.n_classes);
    let mut x = Vec::with_capacity(n * seq_len);
    for i in 0..n {
        x.extend_from_slice(&tokens_for(i, seq_len, vocab));
    }
    let batch = ClsBatch { n, seq_len, x, y: vec![0; n], idx: (0..n).collect() };
    let logits = sess.infer_cls(&params, &batch).unwrap();
    (0..n).map(|i| logits[i * n_classes..(i + 1) * n_classes].to_vec()).collect()
}

/// Serve requests 0..n through a pool with the given config and kernel
/// thread count; logits returned in request order.
fn serve_all(n: usize, cfg: ServeConfig, threads: usize) -> Vec<Vec<f32>> {
    // Follows the env-default tier (like `reference_logits`) so the whole
    // suite stays self-consistent under a VCAS_PRECISION sweep.
    serve_all_tier(n, cfg, threads, vcas::runtime::default_precision())
}

/// `serve_all` with an explicit kernel precision tier on the backend.
fn serve_all_tier(n: usize, cfg: ServeConfig, threads: usize, tier: Precision) -> Vec<Vec<f32>> {
    let backend = Arc::new(
        NativeBackend::with_default_models().with_threads(threads).with_precision(tier),
    );
    let pool = SessionPool::builder(backend).model("tiny").build(cfg).unwrap();
    let info = pool.info("tiny").unwrap();
    let (seq_len, vocab) = (info.seq_len, info.vocab);
    let tickets: Vec<_> = (0..n)
        .map(|i| pool.submit("tiny", tokens_for(i, seq_len, vocab)).unwrap())
        .collect();
    tickets.into_iter().map(|t| t.wait().unwrap().logits).collect()
}

#[test]
fn batching_equivalence_sweep_pool_sizes_and_max_batch() {
    // The determinism contract, swept: every (workers, max_batch) cell —
    // from strictly-serial singles to a 4-worker pool coalescing up to 16
    // rows — must reproduce the reference batched forward bit for bit.
    let n = 12;
    let reference = reference_logits(n);
    for workers in [1usize, 2, 4] {
        for max_batch in [1usize, 4, 16] {
            let cfg = ServeConfig {
                max_batch,
                max_wait: Duration::from_micros(500),
                queue_capacity: 64,
                workers,
            };
            let served = serve_all(n, cfg, 1);
            for (i, (got, want)) in served.iter().zip(&reference).enumerate() {
                assert_eq!(got.len(), want.len());
                let bitwise = got
                    .iter()
                    .zip(want)
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(
                    bitwise,
                    "request {i} diverged at workers={workers} max_batch={max_batch}: \
                     {got:?} vs {want:?}"
                );
            }
        }
    }
}

#[test]
fn serving_is_bitwise_identical_across_kernel_thread_counts() {
    let n = 8;
    let cfg = ServeConfig { max_batch: 8, workers: 2, ..ServeConfig::default() };
    let one = serve_all(n, cfg, 1);
    let two = serve_all(n, cfg, 2);
    for (i, (a, b)) in one.iter().zip(&two).enumerate() {
        assert!(
            a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()),
            "request {i} differs between 1 and 2 kernel threads"
        );
    }
}

#[test]
fn concurrent_singles_coalesce_into_batched_forwards() {
    // With one worker, a generous straggler window and a burst of
    // back-to-back submits, continuous batching must actually batch —
    // otherwise the sweep above proves equivalence of nothing.
    let backend = Arc::new(NativeBackend::with_default_models().with_threads(1));
    let cfg = ServeConfig {
        max_batch: 16,
        max_wait: Duration::from_millis(200),
        queue_capacity: 64,
        workers: 1,
    };
    let pool = SessionPool::builder(backend).model("tiny").build(cfg).unwrap();
    let info = pool.info("tiny").unwrap();
    let (seq_len, vocab) = (info.seq_len, info.vocab);
    let tickets: Vec<_> = (0..8)
        .map(|i| pool.submit("tiny", tokens_for(i, seq_len, vocab)).unwrap())
        .collect();
    let mut max_batched = 0usize;
    for t in tickets {
        let reply = t.wait().unwrap();
        max_batched = max_batched.max(reply.batched);
    }
    assert!(
        max_batched >= 2,
        "8 back-to-back submits inside a 200ms window never shared a forward \
         (max batched {max_batched})"
    );
    assert_eq!(pool.completed("tiny"), 8);
}

#[test]
fn fifo_fairness_under_concurrent_submitters() {
    // One worker: pop order == push order == dense ticket order, and the
    // worker stamps completion sequence numbers in pop order — so
    // done_seq == ticket for EVERY request, no matter how many threads
    // race to submit.
    let backend = Arc::new(NativeBackend::with_default_models().with_threads(1));
    let cfg = ServeConfig {
        max_batch: 4,
        max_wait: Duration::from_micros(100),
        queue_capacity: 64,
        workers: 1,
    };
    let pool = SessionPool::builder(backend).model("tiny").build(cfg).unwrap();
    let info = pool.info("tiny").unwrap();
    let (seq_len, vocab) = (info.seq_len, info.vocab);
    let per_thread = 8usize;
    let pairs: Vec<(u64, u64)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..4)
            .map(|sub| {
                let pool = &pool;
                s.spawn(move || {
                    let mut out = Vec::with_capacity(per_thread);
                    for i in 0..per_thread {
                        let ticket = pool
                            .submit("tiny", tokens_for(sub * per_thread + i, seq_len, vocab))
                            .unwrap();
                        let seq = ticket.ticket();
                        let reply = ticket.wait().unwrap();
                        out.push((seq, reply.done_seq));
                    }
                    out
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    assert_eq!(pairs.len(), 4 * per_thread);
    let mut tickets: Vec<u64> = pairs.iter().map(|&(t, _)| t).collect();
    tickets.sort_unstable();
    assert_eq!(tickets, (0..4 * per_thread as u64).collect::<Vec<_>>(), "tickets not dense");
    for &(ticket, done) in &pairs {
        assert_eq!(done, ticket, "request admitted as #{ticket} completed as #{done}");
    }
}

#[test]
fn admission_control_rejects_overload_and_shuts_down_typed() {
    // No workers: nothing drains, so the queue fills deterministically.
    let backend = Arc::new(NativeBackend::with_default_models());
    let cfg = ServeConfig {
        queue_capacity: 4,
        workers: 0,
        ..ServeConfig::default()
    };
    let pool = SessionPool::builder(backend).model("tiny").build(cfg).unwrap();
    let seq_len = pool.info("tiny").unwrap().seq_len;
    let admitted: Vec<_> =
        (0..4).map(|_| pool.submit("tiny", vec![1; seq_len]).unwrap()).collect();
    assert_eq!(pool.queue_len("tiny"), 4);
    // 5th submit: typed rejection, immediately, with the capacity attached
    match pool.submit("tiny", vec![1; seq_len]) {
        Err(ServingError::Overloaded { model, capacity }) => {
            assert_eq!(model, "tiny");
            assert_eq!(capacity, 4);
        }
        Err(other) => panic!("expected Overloaded, got {other:?}"),
        Ok(_) => panic!("expected Overloaded, got admission"),
    }
    // drop with no workers: the admitted-but-never-drained requests
    // resolve to Shutdown, not a hang
    drop(pool);
    for t in admitted {
        assert_eq!(t.wait().unwrap_err(), ServingError::Shutdown);
    }
}

#[test]
fn drop_mid_flight_joins_workers_and_answers_admitted_requests() {
    let backend = Arc::new(NativeBackend::with_default_models().with_threads(1));
    let cfg = ServeConfig {
        max_batch: 4,
        max_wait: Duration::from_millis(50),
        queue_capacity: 64,
        workers: 2,
    };
    let pool = SessionPool::builder(backend.clone()).model("tiny").build(cfg).unwrap();
    let info = pool.info("tiny").unwrap();
    let (seq_len, vocab, n_classes) = (info.seq_len, info.vocab, info.n_classes);
    let tickets: Vec<_> = (0..6)
        .map(|i| pool.submit("tiny", tokens_for(i, seq_len, vocab)).unwrap())
        .collect();
    // drop while requests are still queued/in flight: close + join must
    // drain them, not abandon them
    drop(pool);
    for t in tickets {
        let reply = t.wait().expect("admitted request must be answered through shutdown");
        assert_eq!(reply.logits.len(), n_classes);
    }
    // join-on-drop actually joined: no detached worker still holds the
    // backend
    assert_eq!(Arc::strong_count(&backend), 1, "worker thread leaked past drop");
}

fn argmax(v: &[f32]) -> usize {
    v.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0
}

#[test]
fn int8_pool_agrees_with_f32_reference() {
    // The int8 tier is a lossy opt-in: per-output-channel weight quant +
    // per-row activation quant bound each linear's error at ~1/127 of its
    // operand range, so logits must land within a small fraction of the
    // row's own scale. Top-1 must survive wherever the f32 margin is
    // decisive (wider than twice the logit tolerance); near-ties are
    // legitimately allowed to flip, so they are excluded rather than
    // letting the test hinge on them.
    let n = 12;
    let reference = reference_logits(n);
    let cfg = ServeConfig {
        max_batch: 8,
        max_wait: Duration::from_micros(500),
        queue_capacity: 64,
        workers: 2,
    };
    let served = serve_all_tier(n, cfg, 1, Precision::Int8Infer);
    let mut decisive = 0usize;
    for (i, (got, want)) in served.iter().zip(&reference).enumerate() {
        assert_eq!(got.len(), want.len());
        let scale = want.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(0.05);
        let tol = 0.10 * scale;
        for (c, (&g, &w)) in got.iter().zip(want).enumerate() {
            assert!(
                (g - w).abs() <= tol,
                "request {i} class {c}: int8 logit {g} vs f32 {w} exceeds tol {tol}"
            );
        }
        let top = argmax(want);
        let mut sorted = want.clone();
        sorted.sort_by(f32::total_cmp);
        let margin = sorted[sorted.len() - 1] - sorted[sorted.len() - 2];
        if margin > 2.0 * tol {
            decisive += 1;
            assert_eq!(
                argmax(got),
                top,
                "request {i}: int8 flipped a decisive top-1 (margin {margin}, tol {tol})"
            );
        }
    }
    assert!(
        decisive > 0,
        "every reference margin was inside the tolerance band; argmax check was vacuous"
    );
}

#[test]
fn int8_tier_is_batch_and_thread_invariant_bitwise() {
    // Within the int8 tier the batching-equivalence contract holds
    // bitwise, same as f32: i32 accumulation is exact (order-free) and
    // the dequant epilogue is per-(row, column), so coalescing and kernel
    // threading cannot move a single bit. Strictly-serial singles are the
    // reference; wide coalescing and a second kernel thread must match.
    let n = 10;
    let singles = ServeConfig {
        max_batch: 1,
        max_wait: Duration::from_micros(100),
        queue_capacity: 64,
        workers: 1,
    };
    let reference = serve_all_tier(n, singles, 1, Precision::Int8Infer);
    let coalesced = ServeConfig {
        max_batch: 16,
        max_wait: Duration::from_micros(500),
        queue_capacity: 64,
        workers: 2,
    };
    for (label, served) in [
        ("coalesced", serve_all_tier(n, coalesced, 1, Precision::Int8Infer)),
        ("two kernel threads", serve_all_tier(n, coalesced, 2, Precision::Int8Infer)),
    ] {
        for (i, (got, want)) in served.iter().zip(&reference).enumerate() {
            assert!(
                got.iter().zip(want).all(|(a, b)| a.to_bits() == b.to_bits()),
                "int8 request {i} diverged from serial singles under {label}"
            );
        }
    }
}
