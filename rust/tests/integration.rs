//! Integration suite over the `Backend` trait.
//!
//! Every test runs hermetically against the pure-Rust `NativeBackend`
//! (shared lazily via `OnceLock` — construction is cheap, sharing keeps the
//! suite honest about `Sync`). No PJRT artifacts, Python, or network are
//! required; `cargo test` passes on a machine that has never run
//! `make artifacts`.
//!
//! The XLA checks (PJRT compile/execute, cross-backend agreement) are
//! compiled behind the `xla` feature and skip gracefully — never hard-fail
//! — when `artifacts/manifest.json` is absent.

use std::sync::OnceLock;

use vcas::config::{Method, TrainConfig, VcasConfig};
use vcas::coordinator::Trainer;
use vcas::data::batch::{gather_cls, EpochSampler};
use vcas::data::tasks::{find, generate_cls};
use vcas::formats::params::ParamSet;
use vcas::runtime::{Backend, ModelKind, ModelSession, NativeBackend, TransformerCfg};
use vcas::util::rng::Pcg32;
use vcas::util::stats::{dist_sq, norm_sq};

fn backend() -> &'static NativeBackend {
    static BACKEND: OnceLock<NativeBackend> = OnceLock::new();
    BACKEND.get_or_init(NativeBackend::with_default_models)
}

fn tiny_batch(seed: u64) -> vcas::data::batch::ClsBatch {
    let sess = ModelSession::open(backend(), "tiny").unwrap();
    let spec = find("sst2-sim").unwrap();
    let ds = generate_cls(&spec, sess.vocab, sess.seq_len, 64, seed);
    let mut sampler = EpochSampler::new(64, seed);
    gather_cls(&ds, &sampler.take(backend().main_batch()))
}

fn ones(sess: &ModelSession) -> (Vec<f32>, Vec<f32>) {
    (vec![1.0f32; sess.n_layers], vec![1.0f32; sess.n_sampled])
}

// ---------------------------------------------------------------------------
// Backend structure.
// ---------------------------------------------------------------------------

#[test]
fn native_registry_params_and_info() {
    let b = backend();
    assert_eq!(b.name(), "native");
    let info = b.info("tiny").expect("tiny registered");
    assert_eq!(info.kind, ModelKind::Transformer);
    let params = b.init_params("tiny").expect("params");
    assert_eq!(params.tensors.len(), info.n_params());
    assert_eq!(params.total_elems(), info.total_elems());
    // embedding is the first tensor by convention and non-degenerate
    assert_eq!(params.tensors[0].name, "embed");
    let rms = (norm_sq(&params.tensors[0].data) / params.tensors[0].numel() as f64).sqrt();
    assert!(rms > 1e-4 && rms < 1.0, "embed rms {rms}");
    // sampled linears resolve to weight tensors, 4 per block
    assert_eq!(info.n_sampled(), 4 * info.n_layers);
    for i in info.sampled_indices() {
        assert!(params.tensors[i].name.contains(".w_"), "{}", params.tensors[i].name);
    }
}

// ---------------------------------------------------------------------------
// Exact-mode semantics.
// ---------------------------------------------------------------------------

#[test]
fn native_exact_grads_bitwise_deterministic_across_seeds() {
    let sess = ModelSession::open(backend(), "tiny").unwrap();
    let params = sess.load_params().unwrap();
    let batch = tiny_batch(1);
    let sw = vec![1.0 / batch.n as f32; batch.n];
    let (ones_l, ones_w) = ones(&sess);
    let a = sess.fwd_bwd_cls(&params, &batch, &sw, 7, &ones_l, &ones_w, &ones_w).unwrap();
    let b = sess.fwd_bwd_cls(&params, &batch, &sw, 991, &ones_l, &ones_w, &ones_w).unwrap();
    // ratios of 1.0 make every mask exactly 1 -> bitwise identical output
    assert_eq!(a.loss.to_bits(), b.loss.to_bits());
    for (ga, gb) in a.grads.iter().zip(&b.grads) {
        assert_eq!(ga, gb, "exact grads must be bitwise identical across seeds");
    }
    // vw must be exactly zero at nu = 1
    assert!(a.vw.iter().all(|&v| v == 0.0), "vw {:?}", a.vw);
}

#[test]
fn native_sampling_changes_grads_but_not_loss() {
    let sess = ModelSession::open(backend(), "tiny").unwrap();
    let params = sess.load_params().unwrap();
    let batch = tiny_batch(2);
    let sw = vec![1.0 / batch.n as f32; batch.n];
    let (ones_l, ones_w) = ones(&sess);
    let rho = vec![0.5f32; sess.n_layers];
    let nu = vec![0.5f32; sess.n_sampled];
    let exact = sess.fwd_bwd_cls(&params, &batch, &sw, 0, &ones_l, &ones_w, &ones_w).unwrap();
    let s1 = sess.fwd_bwd_cls(&params, &batch, &sw, 1, &rho, &nu, &nu).unwrap();
    let s2 = sess.fwd_bwd_cls(&params, &batch, &sw, 2, &rho, &nu, &nu).unwrap();
    // loss comes from the forward pass — sampling must not touch it
    assert!((s1.loss - exact.loss).abs() < 1e-6);
    assert!((s2.loss - exact.loss).abs() < 1e-6);
    // grads are stochastic and differ per seed
    let d12: f64 = s1.grads.iter().zip(&s2.grads).map(|(a, b)| dist_sq(a, b)).sum();
    assert!(d12 > 1e-9, "sampled grads identical across seeds");
    // and vw is positive once nu < 1
    assert!(s1.vw.iter().sum::<f32>() > 0.0);
}

#[test]
fn native_act_norms_and_vw_shapes() {
    let sess = ModelSession::open(backend(), "tiny").unwrap();
    let params = sess.load_params().unwrap();
    let batch = tiny_batch(3);
    let sw = vec![1.0 / batch.n as f32; batch.n];
    let (ones_l, ones_w) = ones(&sess);
    let out = sess.fwd_bwd_cls(&params, &batch, &sw, 0, &ones_l, &ones_w, &ones_w).unwrap();
    assert_eq!(out.act_norms.len(), sess.n_layers * batch.n);
    assert_eq!(out.vw.len(), sess.n_sampled);
    assert!(out.act_norms.iter().all(|&x| x > 0.0 && x.is_finite()));
}

// ---------------------------------------------------------------------------
// Gradient correctness: directional finite differences through the full
// native model (loss from the eval entries, gradient from the grad entry).
// ---------------------------------------------------------------------------

fn micro_backend() -> NativeBackend {
    // Pinned to the f32 tier regardless of VCAS_PRECISION: the tests
    // built on this backend assert f32 semantics (finite differences of
    // a bf16-rounded loss are dominated by rounding at any usable eps,
    // and the unbiasedness sweep targets the f32 estimator).
    let mut b = NativeBackend::new(4, 2, 4).with_precision(vcas::runtime::Precision::F32);
    b.add_transformer(
        "micro",
        TransformerCfg {
            vocab: 16,
            d_model: 8,
            n_heads: 2,
            d_ff: 16,
            n_layers: 1,
            seq_len: 4,
            n_classes: 3,
        },
    );
    b
}

fn micro_cls_batch(n: usize) -> vcas::data::batch::ClsBatch {
    let mut rng = Pcg32::new(77, 0x77);
    let seq_len = 4;
    let x: Vec<i32> = (0..n * seq_len).map(|_| rng.below(16) as i32).collect();
    let y: Vec<i32> = (0..n).map(|_| rng.below(3) as i32).collect();
    vcas::data::batch::ClsBatch { n, seq_len, x, y, idx: vec![] }
}

fn perturb(params: &ParamSet, dir: &[Vec<f32>], eps: f32) -> ParamSet {
    let mut p = params.clone();
    for (t, d) in p.tensors.iter_mut().zip(dir) {
        for (x, &v) in t.data.iter_mut().zip(d) {
            *x += eps * v;
        }
    }
    p
}

fn direction(params: &ParamSet, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg32::new(seed, 0xD1);
    params
        .tensors
        .iter()
        .map(|t| (0..t.numel()).map(|_| rng.normal() as f32).collect())
        .collect()
}

fn dot(grads: &[Vec<f32>], dir: &[Vec<f32>]) -> f64 {
    grads
        .iter()
        .zip(dir)
        .map(|(g, d)| g.iter().zip(d).map(|(&a, &b)| (a * b) as f64).sum::<f64>())
        .sum()
}

#[test]
fn native_cls_backward_matches_finite_differences() {
    let b = micro_backend();
    let sess = ModelSession::open(&b, "micro").unwrap();
    let params = sess.load_params().unwrap();
    let batch = micro_cls_batch(4);
    let sw = vec![1.0 / batch.n as f32; batch.n];
    let (ones_l, ones_w) = ones(&sess);
    let out = sess.fwd_bwd_cls(&params, &batch, &sw, 0, &ones_l, &ones_w, &ones_w).unwrap();
    let eps = 2e-3f32;
    for dseed in [1u64, 2, 3] {
        let dir = direction(&params, dseed);
        let analytic = dot(&out.grads, &dir);
        let (lp, _) = sess.eval_cls(&perturb(&params, &dir, eps), &batch).unwrap();
        let (lm, _) = sess.eval_cls(&perturb(&params, &dir, -eps), &batch).unwrap();
        // eval returns the loss *sum*; fwd_bwd used mean weights 1/N
        let fd = (lp as f64 - lm as f64) / (2.0 * eps as f64 * batch.n as f64);
        assert!(
            (fd - analytic).abs() < 0.02 * analytic.abs().max(0.05),
            "cls dir {dseed}: fd {fd} vs analytic {analytic}"
        );
    }
}

#[test]
fn native_mlm_backward_matches_finite_differences() {
    let b = micro_backend();
    let sess = ModelSession::open(&b, "micro").unwrap();
    let params = sess.load_params().unwrap();
    let n = 3;
    let seq_len = 4;
    let mut rng = Pcg32::new(5, 0x5);
    let x: Vec<i32> = (0..n * seq_len).map(|_| rng.below(16) as i32).collect();
    let y: Vec<i32> = (0..n * seq_len).map(|_| rng.below(16) as i32).collect();
    let w: Vec<f32> = (0..n * seq_len).map(|_| if rng.bernoulli(0.4) { 1.0 } else { 0.0 }).collect();
    let batch = vcas::data::batch::MlmBatch { n, seq_len, x, y, w };
    let (ones_l, ones_w) = ones(&sess);
    let out = sess.fwd_bwd_mlm(&params, &batch, 0, &ones_l, &ones_w, &ones_w).unwrap();
    let eps = 2e-3f32;
    for dseed in [4u64, 5] {
        let dir = direction(&params, dseed);
        let analytic = dot(&out.grads, &dir);
        let (lp, _, wp) = sess.eval_mlm(&perturb(&params, &dir, eps), &batch).unwrap();
        let (lm, _, _) = sess.eval_mlm(&perturb(&params, &dir, -eps), &batch).unwrap();
        let denom = (wp as f64).max(1.0);
        let fd = (lp as f64 - lm as f64) / (2.0 * eps as f64 * denom);
        assert!(
            (fd - analytic).abs() < 0.02 * analytic.abs().max(0.05),
            "mlm dir {dseed}: fd {fd} vs analytic {analytic}"
        );
    }
}

#[test]
fn native_cnn_backward_matches_finite_differences() {
    // f32-pinned for the same reason as micro_backend(): this is a
    // finite-difference check of f32 semantics
    let mut b = NativeBackend::new(4, 2, 4).with_precision(vcas::runtime::Precision::F32);
    b.add_cnn(
        "micro-cnn",
        vcas::runtime::CnnCfg { img: 4, in_ch: 2, widths: vec![3], n_classes: 3 },
    );
    let sess = ModelSession::open(&b, "micro-cnn").unwrap();
    let params = sess.load_params().unwrap();
    let n = 3;
    let mut rng = Pcg32::new(8, 0x8);
    let x: Vec<f32> = (0..n * 4 * 4 * 2).map(|_| rng.normal() as f32).collect();
    let y: Vec<i32> = (0..n).map(|_| rng.below(3) as i32).collect();
    let batch = vcas::data::batch::ImgBatch { n, x, y, idx: vec![] };
    let rho = vec![1.0f32; sess.n_layers];
    let out = sess.cnn_fwd_bwd(&params, &batch, 0, &rho).unwrap();
    let eps = 2e-3f32;
    for dseed in [6u64, 7] {
        let dir = direction(&params, dseed);
        let analytic = dot(&out.grads, &dir);
        let (lp, _) = sess.cnn_eval(&perturb(&params, &dir, eps), &batch).unwrap();
        let (lm, _) = sess.cnn_eval(&perturb(&params, &dir, -eps), &batch).unwrap();
        let fd = (lp as f64 - lm as f64) / (2.0 * eps as f64 * n as f64);
        assert!(
            (fd - analytic).abs() < 0.02 * analytic.abs().max(0.05),
            "cnn dir {dseed}: fd {fd} vs analytic {analytic}"
        );
    }
}

// ---------------------------------------------------------------------------
// Sampler unbiasedness through the full model.
// ---------------------------------------------------------------------------

#[test]
fn native_sampled_gradients_unbiased_over_seeds() {
    let b = micro_backend();
    let sess = ModelSession::open(&b, "micro").unwrap();
    let params = sess.load_params().unwrap();
    let batch = micro_cls_batch(6);
    let sw = vec![1.0 / batch.n as f32; batch.n];
    let (ones_l, ones_w) = ones(&sess);
    let exact = sess.fwd_bwd_cls(&params, &batch, &sw, 0, &ones_l, &ones_w, &ones_w).unwrap();
    let rho = vec![0.5f32; sess.n_layers];
    let nu = vec![0.5f32; sess.n_sampled];
    let reps = 600;
    let mut mean: Vec<Vec<f64>> =
        exact.grads.iter().map(|g| vec![0.0f64; g.len()]).collect();
    for seed in 0..reps {
        let s = sess.fwd_bwd_cls(&params, &batch, &sw, seed, &rho, &nu, &nu).unwrap();
        for (acc, g) in mean.iter_mut().zip(&s.grads) {
            for (a, &x) in acc.iter_mut().zip(g) {
                *a += x as f64;
            }
        }
    }
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (acc, g) in mean.iter().zip(&exact.grads) {
        for (a, &x) in acc.iter().zip(g) {
            let m = a / reps as f64;
            num += (m - x as f64) * (m - x as f64);
            den += (x as f64) * (x as f64);
        }
    }
    let rel = (num / den.max(1e-30)).sqrt();
    assert!(rel < 0.15, "sampled-grad mean deviates from exact: rel {rel}");
}

// ---------------------------------------------------------------------------
// Trainer loops, controller, checkpointing (all native).
// ---------------------------------------------------------------------------

#[test]
fn trainer_all_methods_native() {
    for method in [Method::Exact, Method::Vcas, Method::Sb, Method::Ub, Method::Uniform] {
        let cfg = TrainConfig {
            model: "tiny".into(),
            task: "sst2-sim".into(),
            method: method.clone(),
            steps: 6,
            seed: 3,
            eval_batches: 4,
            vcas: VcasConfig { freq: 3, ..Default::default() },
            ..Default::default()
        };
        let mut t = Trainer::new(backend(), &cfg).unwrap();
        let r = t.run().unwrap();
        assert_eq!(r.losses.len(), 6);
        assert!(
            r.losses.iter().all(|&(_, l)| l.is_finite() && l > 0.0),
            "{}: bad losses {:?}",
            method.name(),
            r.losses
        );
        assert!(r.final_eval_acc >= 0.0 && r.final_eval_acc <= 1.0);
        if matches!(method, Method::Sb | Method::Ub | Method::Uniform) {
            assert!(
                r.flops_reduction > 0.30,
                "{} reduction {}",
                method.name(),
                r.flops_reduction
            );
        }
    }
}

#[test]
fn trainer_runs_are_deterministic() {
    let cfg = TrainConfig {
        model: "tiny".into(),
        task: "sst2-sim".into(),
        method: Method::Vcas,
        steps: 5,
        seed: 11,
        eval_batches: 2,
        vcas: VcasConfig { freq: 2, ..Default::default() },
        ..Default::default()
    };
    let r1 = Trainer::new(backend(), &cfg).unwrap().run().unwrap();
    let r2 = Trainer::new(backend(), &cfg).unwrap().run().unwrap();
    assert_eq!(r1.losses, r2.losses, "same seed must reproduce the loss curve exactly");
    assert_eq!(r1.probes.len(), r2.probes.len());
}

#[test]
fn probe_updates_controller_native() {
    let cfg = TrainConfig {
        model: "tiny".into(),
        task: "sst2-sim".into(),
        method: Method::Vcas,
        steps: 9,
        seed: 5,
        eval_batches: 2,
        vcas: VcasConfig { freq: 4, ..Default::default() },
        ..Default::default()
    };
    let mut t = Trainer::new(backend(), &cfg).unwrap();
    let r = t.run().unwrap();
    // probes at steps 0, 4, 8
    assert_eq!(r.probes.len(), 3, "probe log {:?}", r.probes.len());
    for p in &r.probes {
        assert!(p.v_s > 0.0 && p.v_s.is_finite());
        assert!(p.v_act >= 0.0 && p.v_act.is_finite());
        assert!(p.s > 0.0 && p.s <= 1.0);
        for w in p.rho.windows(2) {
            assert!(w[1] >= w[0], "rho not monotone {:?}", p.rho);
        }
    }
    // the first probe runs at rho = 1 where V_act is exactly 0, so s must
    // take its first downward step off the 1.0 init
    assert!(r.probes[0].s < 1.0);
}

#[test]
fn checkpoint_roundtrip_native() {
    let cfg = TrainConfig {
        model: "tiny".into(),
        task: "sst2-sim".into(),
        method: Method::Exact,
        steps: 3,
        seed: 7,
        eval_batches: 2,
        ..Default::default()
    };
    let mut t = Trainer::new(backend(), &cfg).unwrap();
    let _ = t.run().unwrap();
    let path = std::env::temp_dir().join(format!("vcas_ckpt_{}.bin", std::process::id()));
    t.save_checkpoint(&path).unwrap();
    let info = backend().info("tiny").unwrap();
    let loaded = ParamSet::load_bin(&path, &info.param_specs).unwrap();
    for (a, b) in t.params.tensors.iter().zip(&loaded.tensors) {
        assert_eq!(a.data, b.data, "checkpoint mismatch in {}", a.name);
    }
    // finetune-from-checkpoint path: fresh trainer adopts the params
    let mut t2 = Trainer::new(backend(), &cfg).unwrap();
    t2.set_params(loaded);
    let r2 = t2.run().unwrap();
    assert!(r2.losses[0].1.is_finite());
    let _ = std::fs::remove_file(&path);
}

#[test]
fn cnn_path_native() {
    let cfg = TrainConfig {
        model: "cnn".into(),
        task: "images".into(),
        method: Method::Vcas,
        steps: 4,
        seed: 2,
        eval_batches: 2,
        vcas: VcasConfig { freq: 2, ..Default::default() },
        ..Default::default()
    };
    let mut t = Trainer::new(backend(), &cfg).unwrap();
    let r = t.run().unwrap();
    assert!(r.losses.iter().all(|&(_, l)| l.is_finite()));
    // CNN runs the degraded activation-only mode: nu stays empty
    let (rho, nu) = t.live_ratios();
    assert!(nu.is_empty());
    assert_eq!(rho.len(), 2); // one site per conv stage
    assert!(!r.probes.is_empty());
}

#[test]
fn mlm_path_native() {
    let cfg = TrainConfig {
        model: "tiny".into(),
        task: "mlm".into(),
        method: Method::Vcas,
        steps: 4,
        seed: 2,
        vcas: VcasConfig { freq: 2, ..Default::default() },
        eval_batches: 2,
        ..Default::default()
    };
    let mut t = Trainer::new(backend(), &cfg).unwrap();
    let r = t.run().unwrap();
    assert!(r.losses.iter().all(|&(_, l)| l.is_finite() && l > 0.0));
    // MLM over a 256 vocab starts near ln(256) ~ 5.5
    assert!(r.losses[0].1 > 3.0, "initial mlm loss {:?}", r.losses[0]);
}

// ---------------------------------------------------------------------------
// Threaded kernel layer: results must be bitwise independent of the
// backend's thread count, and the ratio-1 "exact" guarantee must survive
// threading (the PR 2 determinism contract).
// ---------------------------------------------------------------------------

fn cls_batch_for(b: &NativeBackend, model: &str, seed: u64) -> vcas::data::batch::ClsBatch {
    let sess = ModelSession::open(b, model).unwrap();
    let spec = find("sst2-sim").unwrap();
    let ds = generate_cls(&spec, sess.vocab, sess.seq_len, 64, seed);
    let mut sampler = EpochSampler::new(64, seed);
    gather_cls(&ds, &sampler.take(b.main_batch()))
}

fn assert_gradout_bits_eq(a: &vcas::runtime::GradOut, b: &vcas::runtime::GradOut, what: &str) {
    assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "{what}: loss bits differ");
    for (ga, gb) in a.grads.iter().zip(&b.grads) {
        assert_eq!(ga, gb, "{what}: grads differ");
    }
    assert_eq!(a.act_norms, b.act_norms, "{what}: act_norms differ");
    assert_eq!(a.vw, b.vw, "{what}: vw differ");
}

#[test]
fn threaded_fwd_bwd_bitwise_matches_serial() {
    // "small" is big enough (512 rows x d 64) that its matmuls cross the
    // kernel layer's parallel work gate, so threads 2/4 genuinely fan out.
    let serial = NativeBackend::with_default_models().with_threads(1);
    let sess = ModelSession::open(&serial, "small").unwrap();
    let params = sess.load_params().unwrap();
    let batch = cls_batch_for(&serial, "small", 21);
    let sw = vec![1.0 / batch.n as f32; batch.n];
    let (ones_l, ones_w) = ones(&sess);
    let rho = vec![0.5f32; sess.n_layers];
    let nu = vec![0.5f32; sess.n_sampled];

    let exact1 = sess.fwd_bwd_cls(&params, &batch, &sw, 3, &ones_l, &ones_w, &ones_w).unwrap();
    let sampled1 = sess.fwd_bwd_cls(&params, &batch, &sw, 3, &rho, &nu, &nu).unwrap();

    for threads in [2usize, 4] {
        let bt = NativeBackend::with_default_models().with_threads(threads);
        let sess_t = ModelSession::open(&bt, "small").unwrap();
        let exact_t =
            sess_t.fwd_bwd_cls(&params, &batch, &sw, 3, &ones_l, &ones_w, &ones_w).unwrap();
        assert_gradout_bits_eq(&exact1, &exact_t, &format!("exact @ {threads} threads"));
        // sampled path: the rng mask streams are drawn serially, so the
        // same seed gives the same masks — and the same bits — at any
        // thread count
        let sampled_t = sess_t.fwd_bwd_cls(&params, &batch, &sw, 3, &rho, &nu, &nu).unwrap();
        assert_gradout_bits_eq(&sampled1, &sampled_t, &format!("sampled @ {threads} threads"));
    }
}

#[test]
fn threaded_cnn_bitwise_matches_serial() {
    let serial = NativeBackend::with_default_models().with_threads(1);
    let sess = ModelSession::open(&serial, "cnn").unwrap();
    let params = sess.load_params().unwrap();
    let n = serial.cnn_batch();
    let info = serial.info("cnn").unwrap();
    let mut rng = Pcg32::new(31, 0x31);
    let px = info.img * info.img * info.in_ch;
    let x: Vec<f32> = (0..n * px).map(|_| rng.normal() as f32).collect();
    let y: Vec<i32> = (0..n).map(|_| rng.below(info.n_classes as u64) as i32).collect();
    let batch = vcas::data::batch::ImgBatch { n, x, y, idx: vec![] };
    let rho = vec![0.6f32; sess.n_layers];
    let base = sess.cnn_fwd_bwd(&params, &batch, 5, &rho).unwrap();
    for threads in [2usize, 4] {
        let bt = NativeBackend::with_default_models().with_threads(threads);
        let sess_t = ModelSession::open(&bt, "cnn").unwrap();
        let out = sess_t.cnn_fwd_bwd(&params, &batch, 5, &rho).unwrap();
        assert_eq!(base.loss.to_bits(), out.loss.to_bits());
        for (ga, gb) in base.grads.iter().zip(&out.grads) {
            assert_eq!(ga, gb, "cnn grads differ at {threads} threads");
        }
        assert_eq!(base.act_norms, out.act_norms);
    }
}

#[test]
fn ratio1_vcas_bitwise_exact_under_threading() {
    // The seed-PR guarantee — ratios of exactly 1.0 reproduce the exact
    // gradient bitwise across rng seeds — must survive the threaded
    // kernels: masks of exactly 1.0 and disjoint-tile accumulation leave
    // no scheduling fingerprint.
    let bt = NativeBackend::with_default_models().with_threads(4);
    let sess = ModelSession::open(&bt, "small").unwrap();
    let params = sess.load_params().unwrap();
    let batch = cls_batch_for(&bt, "small", 22);
    let sw = vec![1.0 / batch.n as f32; batch.n];
    let (ones_l, ones_w) = ones(&sess);
    let a = sess.fwd_bwd_cls(&params, &batch, &sw, 7, &ones_l, &ones_w, &ones_w).unwrap();
    let b = sess.fwd_bwd_cls(&params, &batch, &sw, 991, &ones_l, &ones_w, &ones_w).unwrap();
    assert_gradout_bits_eq(&a, &b, "ratio-1 across seeds @ 4 threads");
    assert!(a.vw.iter().all(|&v| v == 0.0), "vw must be exactly 0 at nu = 1");
}

#[test]
fn trainer_loss_curve_thread_invariant() {
    let cfg = TrainConfig {
        model: "tiny".into(),
        task: "sst2-sim".into(),
        method: Method::Vcas,
        steps: 5,
        seed: 13,
        eval_batches: 2,
        vcas: VcasConfig { freq: 2, ..Default::default() },
        ..Default::default()
    };
    let b1 = NativeBackend::with_default_models().with_threads(1);
    let b4 = NativeBackend::with_default_models().with_threads(4);
    let r1 = Trainer::new(&b1, &cfg).unwrap().run().unwrap();
    let r4 = Trainer::new(&b4, &cfg).unwrap().run().unwrap();
    assert_eq!(r1.losses, r4.losses, "thread count must not change the training trajectory");
    assert_eq!(r1.final_eval_acc, r4.final_eval_acc);
}

// ---------------------------------------------------------------------------
// Async training pipeline: the prefetch stream must be bitwise invisible —
// the trajectory at any depth equals the synchronous (depth 0) path, for
// every task kind and training method that pulls batches (the PR 5
// determinism contract; `VCAS_PREFETCH=0` pins the sync path suite-wide
// and CI runs the full suite both ways).
// ---------------------------------------------------------------------------

#[test]
fn trainer_loss_curve_prefetch_invariant_cls_and_cnn() {
    for (model, task, method) in [
        ("tiny", "sst2-sim", Method::Vcas),
        ("tiny", "sst2-sim", Method::Sb),
        ("cnn", "images", Method::Vcas),
    ] {
        let base = TrainConfig {
            model: model.into(),
            task: task.into(),
            method: method.clone(),
            steps: 6,
            seed: 17,
            eval_batches: 2,
            vcas: VcasConfig { freq: 3, ..Default::default() },
            ..Default::default()
        };
        let sync_cfg = TrainConfig { prefetch: Some(0), ..base.clone() };
        let mut t0 = Trainer::new(backend(), &sync_cfg).unwrap();
        assert_eq!(t0.prefetch_depth(), 0);
        let r0 = t0.run().unwrap();
        for depth in [1usize, 4] {
            let cfg = TrainConfig { prefetch: Some(depth), ..base.clone() };
            let mut td = Trainer::new(backend(), &cfg).unwrap();
            assert_eq!(td.prefetch_depth(), depth);
            let rd = td.run().unwrap();
            assert_eq!(
                r0.losses, rd.losses,
                "{model}/{task}/{}: depth {depth} changed the trajectory",
                method.name()
            );
            assert_eq!(r0.final_eval_acc, rd.final_eval_acc);
            assert_eq!(r0.flops_actual, rd.flops_actual);
        }
    }
}

#[test]
fn trainer_mlm_forces_sync_prefetch() {
    // MLM masking consumes the trainer's live RNG stream, so any requested
    // depth is forced to 0 — and the trajectory matches an explicit 0.
    let base = TrainConfig {
        model: "tiny".into(),
        task: "mlm".into(),
        method: Method::Vcas,
        steps: 4,
        seed: 9,
        eval_batches: 2,
        vcas: VcasConfig { freq: 2, ..Default::default() },
        ..Default::default()
    };
    let mut forced = Trainer::new(
        backend(),
        &TrainConfig { prefetch: Some(4), ..base.clone() },
    )
    .unwrap();
    assert_eq!(forced.prefetch_depth(), 0, "mlm must force the sync path");
    let rf = forced.run().unwrap();
    let r0 = Trainer::new(backend(), &TrainConfig { prefetch: Some(0), ..base })
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(rf.losses, r0.losses);
}

// ---------------------------------------------------------------------------
// Compacted sampled execution: the gather/scatter backward must be bitwise
// identical to the zero-scan reference at every keep ratio and thread
// count, and steady-state steps must stop allocating through the
// workspace.
// ---------------------------------------------------------------------------

#[test]
fn compacted_transformer_bitwise_matches_zero_scan_ratio_sweep() {
    let params = {
        let b = NativeBackend::with_default_models();
        ModelSession::open(&b, "small").unwrap().load_params().unwrap()
    };
    for threads in [1usize, 2, 4] {
        let zs = NativeBackend::with_default_models()
            .with_threads(threads)
            .with_compaction(false);
        let co = NativeBackend::with_default_models()
            .with_threads(threads)
            .with_compaction(true);
        assert!(!zs.compaction() && co.compaction());
        let sess_z = ModelSession::open(&zs, "small").unwrap();
        let sess_c = ModelSession::open(&co, "small").unwrap();
        let batch = cls_batch_for(&zs, "small", 40 + threads as u64);
        let sw = vec![1.0 / batch.n as f32; batch.n];
        for ratio in [0.1f32, 0.25, 0.5, 0.75, 1.0] {
            let rho = vec![ratio; sess_z.n_layers];
            let nu = vec![ratio; sess_z.n_sampled];
            let a = sess_z.fwd_bwd_cls(&params, &batch, &sw, 9, &rho, &nu, &nu).unwrap();
            let b = sess_c.fwd_bwd_cls(&params, &batch, &sw, 9, &rho, &nu, &nu).unwrap();
            assert_gradout_bits_eq(
                &a,
                &b,
                &format!("compacted vs zero-scan @ ratio {ratio}, {threads} threads"),
            );
        }
    }
}

#[test]
fn compacted_mlm_bitwise_matches_zero_scan() {
    let zs = NativeBackend::with_default_models().with_compaction(false);
    let co = NativeBackend::with_default_models().with_compaction(true);
    let sess_z = ModelSession::open(&zs, "tiny").unwrap();
    let sess_c = ModelSession::open(&co, "tiny").unwrap();
    let params = sess_z.load_params().unwrap();
    let n = zs.main_batch();
    let seq_len = sess_z.seq_len;
    let mut rng = Pcg32::new(61, 0x61);
    let x: Vec<i32> = (0..n * seq_len).map(|_| rng.below(sess_z.vocab as u64) as i32).collect();
    let y: Vec<i32> = (0..n * seq_len).map(|_| rng.below(sess_z.vocab as u64) as i32).collect();
    let w: Vec<f32> =
        (0..n * seq_len).map(|_| if rng.bernoulli(0.3) { 1.0 } else { 0.0 }).collect();
    let batch = vcas::data::batch::MlmBatch { n, seq_len, x, y, w };
    for ratio in [0.2f32, 0.6, 1.0] {
        let rho = vec![ratio; sess_z.n_layers];
        let nu = vec![ratio; sess_z.n_sampled];
        let a = sess_z.fwd_bwd_mlm(&params, &batch, 4, &rho, &nu, &nu).unwrap();
        let b = sess_c.fwd_bwd_mlm(&params, &batch, 4, &rho, &nu, &nu).unwrap();
        assert_gradout_bits_eq(&a, &b, &format!("mlm compacted vs zero-scan @ {ratio}"));
    }
}

#[test]
fn compacted_cnn_bitwise_matches_zero_scan_ratio_sweep() {
    let zs = NativeBackend::with_default_models().with_compaction(false);
    let sess_z = ModelSession::open(&zs, "cnn").unwrap();
    let params = sess_z.load_params().unwrap();
    let info = zs.info("cnn").unwrap();
    let n = zs.cnn_batch();
    let mut rng = Pcg32::new(51, 0x51);
    let px = info.img * info.img * info.in_ch;
    let x: Vec<f32> = (0..n * px).map(|_| rng.normal() as f32).collect();
    let y: Vec<i32> = (0..n).map(|_| rng.below(info.n_classes as u64) as i32).collect();
    let batch = vcas::data::batch::ImgBatch { n, x, y, idx: vec![] };
    for threads in [1usize, 2, 4] {
        let zs_t = NativeBackend::with_default_models()
            .with_threads(threads)
            .with_compaction(false);
        let co_t = NativeBackend::with_default_models()
            .with_threads(threads)
            .with_compaction(true);
        let sz = ModelSession::open(&zs_t, "cnn").unwrap();
        let sc = ModelSession::open(&co_t, "cnn").unwrap();
        for ratio in [0.1f32, 0.5, 1.0] {
            let rho = vec![ratio; sess_z.n_layers];
            let a = sz.cnn_fwd_bwd(&params, &batch, 8, &rho).unwrap();
            let b = sc.cnn_fwd_bwd(&params, &batch, 8, &rho).unwrap();
            assert_eq!(
                a.loss.to_bits(),
                b.loss.to_bits(),
                "cnn loss differs @ ratio {ratio}, {threads} threads"
            );
            for (ga, gb) in a.grads.iter().zip(&b.grads) {
                assert_eq!(ga, gb, "cnn grads differ @ ratio {ratio}, {threads} threads");
            }
            assert_eq!(a.act_norms, b.act_norms, "cnn act_norms differ @ ratio {ratio}");
        }
    }
}

// ---------------------------------------------------------------------------
// SIMD microkernel tier: the lane-width kernels must be bitwise identical
// to the scalar tiles through whole forward/backward passes — at any
// thread count, any keep ratio, with and without compaction (the PR 4
// determinism contract; `VCAS_SIMD=off` pins the scalar tier process-wide
// and CI runs the full suite both ways).
// ---------------------------------------------------------------------------

#[test]
fn simd_fwd_bwd_bitwise_matches_scalar_tier() {
    let params = {
        let b = NativeBackend::with_default_models();
        ModelSession::open(&b, "small").unwrap().load_params().unwrap()
    };
    for threads in [1usize, 4] {
        for compact in [false, true] {
            let scalar = NativeBackend::with_default_models()
                .with_threads(threads)
                .with_compaction(compact)
                .with_simd(false);
            let vect = NativeBackend::with_default_models()
                .with_threads(threads)
                .with_compaction(compact)
                .with_simd(true);
            let sess_s = ModelSession::open(&scalar, "small").unwrap();
            let sess_v = ModelSession::open(&vect, "small").unwrap();
            let batch = cls_batch_for(&scalar, "small", 80 + threads as u64);
            let sw = vec![1.0 / batch.n as f32; batch.n];
            for ratio in [0.25f32, 1.0] {
                let rho = vec![ratio; sess_s.n_layers];
                let nu = vec![ratio; sess_s.n_sampled];
                let a = sess_s.fwd_bwd_cls(&params, &batch, &sw, 11, &rho, &nu, &nu).unwrap();
                let b = sess_v.fwd_bwd_cls(&params, &batch, &sw, 11, &rho, &nu, &nu).unwrap();
                assert_gradout_bits_eq(
                    &a,
                    &b,
                    &format!(
                        "simd vs scalar @ ratio {ratio}, {threads} threads, compact {compact}"
                    ),
                );
            }
        }
    }
}

#[test]
fn simd_cnn_bitwise_matches_scalar_tier() {
    let b0 = NativeBackend::with_default_models();
    let info = b0.info("cnn").unwrap();
    let params = ModelSession::open(&b0, "cnn").unwrap().load_params().unwrap();
    let n = b0.cnn_batch();
    let mut rng = Pcg32::new(71, 0x71);
    let px = info.img * info.img * info.in_ch;
    let x: Vec<f32> = (0..n * px).map(|_| rng.normal() as f32).collect();
    let y: Vec<i32> = (0..n).map(|_| rng.below(info.n_classes as u64) as i32).collect();
    let batch = vcas::data::batch::ImgBatch { n, x, y, idx: vec![] };
    for threads in [1usize, 2] {
        for compact in [false, true] {
            let scalar = NativeBackend::with_default_models()
                .with_threads(threads)
                .with_compaction(compact)
                .with_simd(false);
            let vect = NativeBackend::with_default_models()
                .with_threads(threads)
                .with_compaction(compact)
                .with_simd(true);
            let ss = ModelSession::open(&scalar, "cnn").unwrap();
            let sv = ModelSession::open(&vect, "cnn").unwrap();
            for ratio in [0.3f32, 1.0] {
                let rho = vec![ratio; ss.n_layers];
                let a = ss.cnn_fwd_bwd(&params, &batch, 6, &rho).unwrap();
                let b = sv.cnn_fwd_bwd(&params, &batch, 6, &rho).unwrap();
                assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "cnn loss @ ratio {ratio}");
                for (ga, gb) in a.grads.iter().zip(&b.grads) {
                    assert_eq!(
                        ga, gb,
                        "cnn simd grads differ @ ratio {ratio}, {threads} thr, compact {compact}"
                    );
                }
                assert_eq!(a.act_norms, b.act_norms);
            }
        }
    }
}

#[test]
fn workspace_reuse_steady_state_no_allocations() {
    // Steady-state training steps must perform no per-step matmul output
    // allocations: after a warm-up step populates the pool, further
    // identical steps reuse every buffer.
    let b = NativeBackend::with_default_models(); // private instance: counters undisturbed
    let sess = ModelSession::open(&b, "small").unwrap();
    let params = sess.load_params().unwrap();
    let batch = cls_batch_for(&b, "small", 77);
    let sw = vec![1.0 / batch.n as f32; batch.n];
    let rho = vec![0.5f32; sess.n_layers];
    let nu = vec![0.5f32; sess.n_sampled];
    // Fixed seed: identical steps issue an identical buffer-request
    // sequence, so after one warm-up step the pool must cover every
    // subsequent step deterministically. (Across seeds the kept-set sizes
    // move, and a step keeping more rows than any prior one may grow a
    // buffer once — that is warm-up, not steady state.)
    for _ in 0..2 {
        sess.fwd_bwd_cls(&params, &batch, &sw, 7, &rho, &nu, &nu).unwrap();
    }
    let warm_allocs = b.workspace().allocations();
    let warm_takes = b.workspace().takes();
    assert!(warm_takes > 0, "fwd_bwd must route buffers through the workspace");
    for _ in 0..4 {
        sess.fwd_bwd_cls(&params, &batch, &sw, 7, &rho, &nu, &nu).unwrap();
    }
    assert_eq!(
        b.workspace().allocations(),
        warm_allocs,
        "steady-state steps must not allocate fresh buffers"
    );
    assert!(b.workspace().takes() > warm_takes);
}

// ---------------------------------------------------------------------------
// XLA checks: feature- and artifact-gated, with graceful skips.
// ---------------------------------------------------------------------------

#[cfg(feature = "xla")]
mod xla_checks {
    use super::*;
    use std::path::{Path, PathBuf};
    use vcas::runtime::XlaBackend;

    fn artifacts_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn load_xla() -> Option<XlaBackend> {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            println!("artifacts missing — XLA checks skipped (run `make artifacts`)");
            return None;
        }
        Some(XlaBackend::load(&dir).expect("artifacts present but engine failed to load"))
    }

    /// When artifacts are present, NativeBackend and XlaBackend exact-mode
    /// (rho = nu = 1) losses/gradients agree on the same seeded batch.
    #[test]
    fn cross_backend_exact_mode_agreement() {
        let Some(xla) = load_xla() else { return };
        let info = xla.info("tiny").expect("tiny in manifest");
        // f32-pinned: the XLA artifacts are f32, and the tolerance is tight
        let mut native = NativeBackend::new(xla.main_batch(), xla.sub_batch(), xla.cnn_batch())
            .with_precision(vcas::runtime::Precision::F32);
        native.add_from_info(&info).unwrap();
        let params = xla.init_params("tiny").unwrap();

        let spec = find("sst2-sim").unwrap();
        let ds = generate_cls(&spec, info.vocab, info.seq_len, 64, 9);
        let mut sampler = EpochSampler::new(64, 9);
        let batch = gather_cls(&ds, &sampler.take(xla.main_batch()));
        let sw = vec![1.0 / batch.n as f32; batch.n];
        let ones_l = vec![1.0f32; info.n_layers];
        let ones_w = vec![1.0f32; info.n_sampled()];

        let gx = xla
            .fwd_bwd_cls("tiny", &params, &batch, &sw, 0, &ones_l, &ones_w, &ones_w)
            .unwrap();
        let gn = native
            .fwd_bwd_cls("tiny", &params, &batch, &sw, 0, &ones_l, &ones_w, &ones_w)
            .unwrap();
        assert!(
            (gx.loss - gn.loss).abs() < 1e-4 * gx.loss.abs().max(1.0),
            "loss {} vs {}",
            gx.loss,
            gn.loss
        );
        for ((tx, tn), (name, _)) in gx.grads.iter().zip(&gn.grads).zip(&info.param_specs) {
            let d = dist_sq(tx, tn).sqrt();
            let scale = norm_sq(tx).sqrt().max(1e-9);
            assert!(d / scale < 3e-3, "{name}: grads diverge ({d} vs scale {scale})");
        }
    }

    /// Trainer smoke through the PJRT path when artifacts exist.
    #[test]
    fn xla_trainer_smoke() {
        let Some(xla) = load_xla() else { return };
        let cfg = TrainConfig {
            model: "tiny".into(),
            task: "sst2-sim".into(),
            method: Method::Vcas,
            steps: 4,
            seed: 3,
            eval_batches: 2,
            vcas: VcasConfig { freq: 2, ..Default::default() },
            ..Default::default()
        };
        let r = Trainer::new(&xla, &cfg).unwrap().run().unwrap();
        assert!(r.losses.iter().all(|&(_, l)| l.is_finite() && l > 0.0));
    }
}

// ---------------------------------------------------------------------------
// Per-layer gradient hooks: the hooked backward entries must publish every
// tensor exactly once, bitwise equal to the returned gradients, without
// perturbing the output — the contract the overlapped DDP reducer builds
// on (a missing or doubled publish would deadlock or corrupt a bucket).
// ---------------------------------------------------------------------------

struct RecordingHook {
    slots: std::sync::Mutex<Vec<Option<Vec<f32>>>>,
}

impl RecordingHook {
    fn new(n: usize) -> RecordingHook {
        RecordingHook { slots: std::sync::Mutex::new(vec![None; n]) }
    }

    fn into_slots(self) -> Vec<Option<Vec<f32>>> {
        self.slots.into_inner().unwrap()
    }
}

impl vcas::runtime::GradHook for RecordingHook {
    fn on_grad(&self, tensor: usize, grad: &[f32]) -> vcas::error::Result<()> {
        let mut slots = self.slots.lock().unwrap();
        if slots[tensor].is_some() {
            vcas::bail!("tensor {tensor} published twice");
        }
        slots[tensor] = Some(grad.to_vec());
        Ok(())
    }
}

fn assert_published_matches(published: Vec<Option<Vec<f32>>>, grads: &[Vec<f32>], tag: &str) {
    assert_eq!(published.len(), grads.len(), "{tag}: published tensor count");
    for (t, (slot, g)) in published.iter().zip(grads).enumerate() {
        let p = slot
            .as_ref()
            .unwrap_or_else(|| panic!("{tag}: tensor {t} never published"));
        assert_eq!(p.len(), g.len(), "{tag}: tensor {t} length");
        assert!(
            p.iter().zip(g).all(|(a, b)| a.to_bits() == b.to_bits()),
            "{tag}: tensor {t} published bytes differ from returned grads"
        );
    }
}

#[test]
fn hooked_cls_backward_publishes_every_tensor_bitwise() {
    let b = backend();
    let sess = ModelSession::open(b, "tiny").unwrap();
    let params = sess.load_params().unwrap();
    let batch = tiny_batch(23);
    let sw = vec![1.0 / batch.n as f32; batch.n];
    let (ones_l, ones_w) = ones(&sess);
    let half_l = vec![0.5f32; sess.n_layers];
    let half_w = vec![0.5f32; sess.n_sampled];
    for (tag, rho, nu) in [
        ("cls exact", &ones_l, &ones_w),
        ("cls sampled", &half_l, &half_w),
    ] {
        let plain = b
            .fwd_bwd_cls("tiny", &params, &batch, &sw, 3, rho, nu, nu)
            .unwrap();
        let hook = RecordingHook::new(plain.grads.len());
        let hooked = b
            .fwd_bwd_cls_hooked("tiny", &params, &batch, &sw, 3, rho, nu, nu, &hook)
            .unwrap();
        assert_gradout_bits_eq(&plain, &hooked, tag);
        assert_published_matches(hook.into_slots(), &hooked.grads, tag);
    }
}

#[test]
fn hooked_mlm_backward_publishes_every_tensor_bitwise() {
    let b = backend();
    let sess = ModelSession::open(b, "tiny").unwrap();
    let params = sess.load_params().unwrap();
    let n = b.main_batch();
    let seq_len = sess.seq_len;
    let mut rng = Pcg32::new(31, 0x31);
    let x: Vec<i32> = (0..n * seq_len).map(|_| rng.below(sess.vocab as u64) as i32).collect();
    let y: Vec<i32> = (0..n * seq_len).map(|_| rng.below(sess.vocab as u64) as i32).collect();
    let w: Vec<f32> =
        (0..n * seq_len).map(|_| if rng.bernoulli(0.2) { 1.0 } else { 0.0 }).collect();
    let batch = vcas::data::batch::MlmBatch { n, seq_len, x, y, w };
    let (ones_l, ones_w) = ones(&sess);
    let plain = b
        .fwd_bwd_mlm("tiny", &params, &batch, 5, &ones_l, &ones_w, &ones_w)
        .unwrap();
    let hook = RecordingHook::new(plain.grads.len());
    let hooked = b
        .fwd_bwd_mlm_hooked("tiny", &params, &batch, 5, &ones_l, &ones_w, &ones_w, &hook)
        .unwrap();
    assert_gradout_bits_eq(&plain, &hooked, "mlm exact");
    assert_published_matches(hook.into_slots(), &hooked.grads, "mlm exact");
}

#[test]
fn hooked_cnn_backward_publishes_every_tensor_bitwise() {
    let b = backend();
    let info = b.info("cnn").unwrap();
    let sess = ModelSession::open(b, "cnn").unwrap();
    let params = sess.load_params().unwrap();
    let n = b.cnn_batch();
    let mut rng = Pcg32::new(37, 0x37);
    let x: Vec<f32> =
        (0..n * info.img * info.img * info.in_ch).map(|_| rng.normal() as f32).collect();
    let y: Vec<i32> = (0..n).map(|_| rng.below(info.n_classes as u64) as i32).collect();
    let batch = vcas::data::batch::ImgBatch { n, x, y, idx: vec![] };
    let ones_sites = vec![1.0f32; sess.n_layers];
    let half_sites = vec![0.5f32; sess.n_layers];
    for (tag, rho) in [("cnn exact", &ones_sites), ("cnn sampled", &half_sites)] {
        let plain = b.cnn_fwd_bwd("cnn", &params, &batch, 7, rho).unwrap();
        let hook = RecordingHook::new(plain.grads.len());
        let hooked = b.cnn_fwd_bwd_hooked("cnn", &params, &batch, 7, rho, &hook).unwrap();
        assert_eq!(plain.loss.to_bits(), hooked.loss.to_bits(), "{tag}: loss");
        for (t, (ga, gb)) in plain.grads.iter().zip(&hooked.grads).enumerate() {
            assert_eq!(ga, gb, "{tag}: tensor {t} grads differ");
        }
        assert_published_matches(hook.into_slots(), &hooked.grads, tag);
    }
}

// ---------------------------------------------------------------------------
// Reduced-precision tier (bf16 storage / f32 accumulate). Unlike threads,
// SIMD and compaction this tier deliberately changes numerics, so the
// contract is tolerance-based: bf16 results must track the
// finite-difference-verified f32 gradients within rounding-level bounds.
// Losses are forward-only (sampling never touches them) and stay tight at
// every keep ratio. Exact-mode (ratio 1.0) gradients are a pure arithmetic
// comparison — the samplers draw q = 1 in both tiers — and stay tight too.
// Sampled gradients get a loose bound only: the Bern(q)/q draws compare
// the same uniforms against slightly different q's, so a handful of mask
// flips near the boundary are legitimate, and each flipped *sample* swings
// O(1/(N·q)) of the gradient norm. Within the tier, bitwise determinism
// across threads and compaction still holds (asserted below).
// ---------------------------------------------------------------------------

/// Norm-wise relative error over concatenated gradient tensors,
/// `||a - b|| / ||b||` in f64.
fn grads_rel_err(a: &[Vec<f32>], b: &[Vec<f32>]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (ga, gb) in a.iter().zip(b) {
        num += dist_sq(ga, gb);
        den += norm_sq(gb);
    }
    (num / den.max(1e-30)).sqrt()
}

fn bf16_backend(threads: usize) -> NativeBackend {
    NativeBackend::with_default_models()
        .with_threads(threads)
        .with_precision(vcas::runtime::Precision::Bf16)
}

/// The f32 side of the comparisons, pinned explicitly so a
/// `VCAS_PRECISION=bf16` sweep can't turn these into bf16-vs-bf16
/// no-ops (the drift-is-nonzero assertions below require a real f32
/// baseline).
fn f32_pinned_backend(threads: usize) -> NativeBackend {
    NativeBackend::with_default_models()
        .with_threads(threads)
        .with_precision(vcas::runtime::Precision::F32)
}

#[test]
fn bf16_fwd_bwd_tracks_f32_within_tolerance_cls_and_mlm() {
    let f32_b = NativeBackend::with_default_models();
    let params = ModelSession::open(&f32_b, "small").unwrap().load_params().unwrap();
    for threads in [1usize, 2] {
        let fb = f32_pinned_backend(threads);
        let qb = bf16_backend(threads);
        let sess_f = ModelSession::open(&fb, "small").unwrap();
        let sess_q = ModelSession::open(&qb, "small").unwrap();
        let batch = cls_batch_for(&fb, "small", 90 + threads as u64);
        let sw = vec![1.0 / batch.n as f32; batch.n];
        for keep in [0.25f32, 0.5, 1.0] {
            let rho = vec![keep; sess_f.n_layers];
            let nu = vec![keep; sess_f.n_sampled];
            let a = sess_f.fwd_bwd_cls(&params, &batch, &sw, 13, &rho, &nu, &nu).unwrap();
            let b = sess_q.fwd_bwd_cls(&params, &batch, &sw, 13, &rho, &nu, &nu).unwrap();
            let dl = ((a.loss - b.loss).abs() / a.loss.abs().max(1e-6)) as f64;
            assert!(dl < 0.05, "cls loss drift {dl} @ keep {keep}, {threads} threads");
            let dg = grads_rel_err(&b.grads, &a.grads);
            assert!(b.grads.iter().flatten().all(|g| g.is_finite()));
            if keep == 1.0 {
                assert!(dg < 0.10, "cls exact-mode grad drift {dg} @ {threads} threads");
                // the tier must actually engage: bitwise-f32 bf16 would
                // mean the dispatch is dead code
                assert!(dg > 0.0, "bf16 produced bitwise-f32 grads");
            } else {
                assert!(dg < 1.5, "cls sampled grad drift {dg} @ keep {keep}");
            }
        }
    }
    // mlm path, exact mode: tight bound through the tied-embedding head
    let fb = f32_pinned_backend(2);
    let qb = bf16_backend(2);
    let sess_f = ModelSession::open(&fb, "tiny").unwrap();
    let sess_q = ModelSession::open(&qb, "tiny").unwrap();
    let tparams = sess_f.load_params().unwrap();
    let n = fb.main_batch();
    let seq_len = sess_f.seq_len;
    let mut rng = Pcg32::new(97, 0x97);
    let x: Vec<i32> = (0..n * seq_len).map(|_| rng.below(sess_f.vocab as u64) as i32).collect();
    let y: Vec<i32> = (0..n * seq_len).map(|_| rng.below(sess_f.vocab as u64) as i32).collect();
    let w: Vec<f32> =
        (0..n * seq_len).map(|_| if rng.bernoulli(0.3) { 1.0 } else { 0.0 }).collect();
    let batch = vcas::data::batch::MlmBatch { n, seq_len, x, y, w };
    let ones_l = vec![1.0f32; sess_f.n_layers];
    let ones_w = vec![1.0f32; sess_f.n_sampled];
    let a = sess_f.fwd_bwd_mlm(&tparams, &batch, 17, &ones_l, &ones_w, &ones_w).unwrap();
    let b = sess_q.fwd_bwd_mlm(&tparams, &batch, 17, &ones_l, &ones_w, &ones_w).unwrap();
    let dl = ((a.loss - b.loss).abs() / a.loss.abs().max(1e-6)) as f64;
    assert!(dl < 0.05, "mlm loss drift {dl}");
    let dg = grads_rel_err(&b.grads, &a.grads);
    assert!(dg < 0.10, "mlm exact-mode grad drift {dg}");
}

#[test]
fn bf16_fwd_bwd_tracks_f32_within_tolerance_cnn() {
    let b0 = NativeBackend::with_default_models();
    let info = b0.info("cnn").unwrap();
    let params = ModelSession::open(&b0, "cnn").unwrap().load_params().unwrap();
    let n = b0.cnn_batch();
    let mut rng = Pcg32::new(93, 0x93);
    let px = info.img * info.img * info.in_ch;
    let x: Vec<f32> = (0..n * px).map(|_| rng.normal() as f32).collect();
    let y: Vec<i32> = (0..n).map(|_| rng.below(info.n_classes as u64) as i32).collect();
    let batch = vcas::data::batch::ImgBatch { n, x, y, idx: vec![] };
    for threads in [1usize, 2] {
        let fb = f32_pinned_backend(threads);
        let qb = bf16_backend(threads);
        let sf = ModelSession::open(&fb, "cnn").unwrap();
        let sq = ModelSession::open(&qb, "cnn").unwrap();
        for keep in [0.25f32, 0.5, 1.0] {
            let rho = vec![keep; sf.n_layers];
            let a = sf.cnn_fwd_bwd(&params, &batch, 19, &rho).unwrap();
            let b = sq.cnn_fwd_bwd(&params, &batch, 19, &rho).unwrap();
            let dl = ((a.loss - b.loss).abs() / a.loss.abs().max(1e-6)) as f64;
            assert!(dl < 0.05, "cnn loss drift {dl} @ keep {keep}, {threads} threads");
            let dg = grads_rel_err(&b.grads, &a.grads);
            assert!(b.grads.iter().flatten().all(|g| g.is_finite()));
            if keep == 1.0 {
                assert!(dg < 0.10, "cnn exact-mode grad drift {dg} @ {threads} threads");
            } else {
                assert!(dg < 1.5, "cnn sampled grad drift {dg} @ keep {keep}");
            }
        }
    }
}

/// bf16 breaks bitwise agreement *with f32*, not with itself: inside the
/// tier the determinism contract still holds — same bits at any thread
/// count and with compaction on or off (the gather path rounds
/// elementwise, so packed rows decode to exactly the zero-scan values).
#[test]
fn bf16_tier_is_bitwise_deterministic_within_itself() {
    let params = {
        let b = NativeBackend::with_default_models();
        ModelSession::open(&b, "small").unwrap().load_params().unwrap()
    };
    let reference = {
        let b = bf16_backend(1).with_compaction(false);
        let sess = ModelSession::open(&b, "small").unwrap();
        let batch = cls_batch_for(&b, "small", 140);
        let sw = vec![1.0 / batch.n as f32; batch.n];
        let rho = vec![0.5f32; sess.n_layers];
        let nu = vec![0.5f32; sess.n_sampled];
        sess.fwd_bwd_cls(&params, &batch, &sw, 21, &rho, &nu, &nu).unwrap()
    };
    for threads in [2usize, 4] {
        for compact in [false, true] {
            let b = bf16_backend(threads).with_compaction(compact);
            let sess = ModelSession::open(&b, "small").unwrap();
            let batch = cls_batch_for(&b, "small", 140);
            let sw = vec![1.0 / batch.n as f32; batch.n];
            let rho = vec![0.5f32; sess.n_layers];
            let nu = vec![0.5f32; sess.n_sampled];
            let out = sess.fwd_bwd_cls(&params, &batch, &sw, 21, &rho, &nu, &nu).unwrap();
            assert_gradout_bits_eq(
                &reference,
                &out,
                &format!("bf16 internal determinism @ {threads} threads, compact {compact}"),
            );
        }
    }
}
