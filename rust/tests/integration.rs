//! Integration tests over the real artifacts: PJRT load/compile/execute,
//! estimator semantics through the full stack, trainer loops for every
//! method, checkpointing, and the CNN path.
//!
//! Executable compilation dominates the cost, so everything shares one
//! Engine inside a single #[test] (the engine's executable cache is not
//! Sync; splitting into many tests would recompile per test).

use std::path::{Path, PathBuf};

use vcas::config::{Method, TrainConfig, VcasConfig};
use vcas::coordinator::Trainer;
use vcas::data::batch::{gather_cls, EpochSampler};
use vcas::data::tasks::{find, generate_cls};
use vcas::formats::params::ParamSet;
use vcas::runtime::{Engine, ModelSession};
use vcas::util::stats::dist_sq;

fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[test]
fn full_stack_suite() {
    let dir = artifacts_dir();
    assert!(
        dir.join("manifest.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    let engine = Engine::load(&dir).expect("engine load");
    println!("platform: {}", engine.platform());

    check_manifest_and_params(&engine);
    check_pallas_and_ref_paths_agree(&engine);
    check_exact_grad_determinism(&engine);
    check_sampling_changes_grads_but_not_loss_path(&engine);
    check_act_norms_and_vw_shapes(&engine);
    check_trainer_all_methods(&engine);
    check_probe_updates_controller(&engine);
    check_checkpoint_roundtrip(&engine);
    check_cnn_path(&engine);
    check_mlm_path(&engine);
}

fn check_manifest_and_params(engine: &Engine) {
    let m = engine.model("tiny").expect("tiny in manifest");
    assert_eq!(m.kind, "transformer");
    let params = engine.load_params("tiny").expect("params load");
    assert_eq!(params.tensors.len(), m.param_specs.len());
    // embedding is the first tensor by convention and non-degenerate
    assert_eq!(params.tensors[0].name, "embed");
    let rms = (vcas::util::stats::norm_sq(&params.tensors[0].data)
        / params.tensors[0].numel() as f64)
        .sqrt();
    assert!(rms > 1e-4 && rms < 1.0, "embed rms {rms}");
    println!("manifest+params ok ({} tensors)", params.tensors.len());
}

/// "tiny" lowers the samplers through the pure-jnp reference path, "tinyp"
/// through the Pallas kernels — same architecture, same init seed. Their
/// exact-mode gradients must agree to float tolerance, proving the L1
/// kernels compose through AOT + PJRT identically to the oracle.
fn check_pallas_and_ref_paths_agree(engine: &Engine) {
    if engine.model("tinyp").is_err() {
        println!("tinyp artifacts not built — skipping cross-path check");
        return;
    }
    let a = ModelSession::open(engine, "tiny").unwrap();
    let b = ModelSession::open(engine, "tinyp").unwrap();
    let pa = a.load_params().unwrap();
    let pb = b.load_params().unwrap();
    let batch = tiny_batch(engine, 9);
    let sw = vec![1.0 / batch.n as f32; batch.n];
    let ones_l = vec![1.0f32; a.n_layers];
    let ones_w = vec![1.0f32; a.n_sampled];
    let ga = a.fwd_bwd_cls(&pa, &batch, &sw, 0, &ones_l, &ones_w, &ones_w).unwrap();
    let gb = b.fwd_bwd_cls(&pb, &batch, &sw, 0, &ones_l, &ones_w, &ones_w).unwrap();
    assert!((ga.loss - gb.loss).abs() < 1e-5, "loss {} vs {}", ga.loss, gb.loss);
    for (ta, tb) in ga.grads.iter().zip(&gb.grads) {
        let d = dist_sq(ta, tb).sqrt();
        let scale = vcas::util::stats::norm_sq(ta).sqrt().max(1e-9);
        assert!(d / scale < 1e-3, "pallas/ref grads diverge: {d} vs scale {scale}");
    }
    println!("pallas/ref cross-path agreement ok");
}

fn tiny_batch(engine: &Engine, seed: u64) -> vcas::data::batch::ClsBatch {
    let sess = ModelSession::open(engine, "tiny").unwrap();
    let spec = find("sst2-sim").unwrap();
    let ds = generate_cls(&spec, sess.vocab, sess.seq_len, 64, seed);
    let mut sampler = EpochSampler::new(64, seed);
    gather_cls(&ds, &sampler.take(engine.manifest.main_batch))
}

fn check_exact_grad_determinism(engine: &Engine) {
    let sess = ModelSession::open(engine, "tiny").unwrap();
    let params = sess.load_params().unwrap();
    let batch = tiny_batch(engine, 1);
    let sw = vec![1.0 / batch.n as f32; batch.n];
    let ones_l = vec![1.0f32; sess.n_layers];
    let ones_w = vec![1.0f32; sess.n_sampled];
    let a = sess
        .fwd_bwd_cls(&params, &batch, &sw, 7, &ones_l, &ones_w, &ones_w)
        .unwrap();
    let b = sess
        .fwd_bwd_cls(&params, &batch, &sw, 991, &ones_l, &ones_w, &ones_w)
        .unwrap();
    assert!((a.loss - b.loss).abs() < 1e-6);
    for (ga, gb) in a.grads.iter().zip(&b.grads) {
        assert!(dist_sq(ga, gb) < 1e-10, "exact grads differ across seeds");
    }
    // vw must be exactly zero at nu = 1
    assert!(a.vw.iter().all(|&v| v.abs() < 1e-8));
    println!("exact determinism ok (loss {:.4})", a.loss);
}

fn check_sampling_changes_grads_but_not_loss_path(engine: &Engine) {
    let sess = ModelSession::open(engine, "tiny").unwrap();
    let params = sess.load_params().unwrap();
    let batch = tiny_batch(engine, 2);
    let sw = vec![1.0 / batch.n as f32; batch.n];
    let ones_l = vec![1.0f32; sess.n_layers];
    let ones_w = vec![1.0f32; sess.n_sampled];
    let rho = vec![0.5f32; sess.n_layers];
    let nu = vec![0.5f32; sess.n_sampled];
    let exact = sess
        .fwd_bwd_cls(&params, &batch, &sw, 0, &ones_l, &ones_w, &ones_w)
        .unwrap();
    let s1 = sess.fwd_bwd_cls(&params, &batch, &sw, 1, &rho, &nu, &nu).unwrap();
    let s2 = sess.fwd_bwd_cls(&params, &batch, &sw, 2, &rho, &nu, &nu).unwrap();
    // loss comes from the forward pass — sampling must not touch it
    assert!((s1.loss - exact.loss).abs() < 1e-6);
    assert!((s2.loss - exact.loss).abs() < 1e-6);
    // grads are stochastic and differ per seed
    let d12: f64 = s1.grads.iter().zip(&s2.grads).map(|(a, b)| dist_sq(a, b)).sum();
    assert!(d12 > 1e-9, "sampled grads identical across seeds");
    // and vw is positive once nu < 1
    assert!(s1.vw.iter().sum::<f32>() > 0.0);
    println!("sampling semantics ok (grad diff {d12:.3e})");
}

fn check_act_norms_and_vw_shapes(engine: &Engine) {
    let sess = ModelSession::open(engine, "tiny").unwrap();
    let params = sess.load_params().unwrap();
    let batch = tiny_batch(engine, 3);
    let sw = vec![1.0 / batch.n as f32; batch.n];
    let ones_l = vec![1.0f32; sess.n_layers];
    let ones_w = vec![1.0f32; sess.n_sampled];
    let out = sess
        .fwd_bwd_cls(&params, &batch, &sw, 0, &ones_l, &ones_w, &ones_w)
        .unwrap();
    assert_eq!(out.act_norms.len(), sess.n_layers * batch.n);
    assert_eq!(out.vw.len(), sess.n_sampled);
    assert!(out.act_norms.iter().all(|&x| x > 0.0 && x.is_finite()));
    println!("probe output shapes ok");
}

fn check_trainer_all_methods(engine: &Engine) {
    for method in [Method::Exact, Method::Vcas, Method::Sb, Method::Ub, Method::Uniform] {
        let cfg = TrainConfig {
            model: "tiny".into(),
            task: "sst2-sim".into(),
            method: method.clone(),
            steps: 6,
            seed: 3,
            vcas: VcasConfig { freq: 3, ..Default::default() },
            ..Default::default()
        };
        let mut t = Trainer::new(engine, &cfg).unwrap();
        let r = t.run().unwrap();
        assert_eq!(r.losses.len(), 6);
        assert!(
            r.losses.iter().all(|&(_, l)| l.is_finite() && l > 0.0),
            "{}: bad losses {:?}",
            method.name(),
            r.losses
        );
        assert!(r.final_eval_acc >= 0.0 && r.final_eval_acc <= 1.0);
        if matches!(method, Method::Sb | Method::Ub | Method::Uniform) {
            assert!(
                r.flops_reduction > 0.30,
                "{} reduction {}",
                method.name(),
                r.flops_reduction
            );
        }
        println!(
            "trainer {} ok: loss {:.3} -> {:.3}, flops red {:.1}%",
            method.name(),
            r.losses[0].1,
            r.losses[5].1,
            r.flops_reduction * 100.0
        );
    }
}

fn check_probe_updates_controller(engine: &Engine) {
    let cfg = TrainConfig {
        model: "tiny".into(),
        task: "sst2-sim".into(),
        method: Method::Vcas,
        steps: 9,
        seed: 5,
        vcas: VcasConfig { freq: 4, ..Default::default() },
        ..Default::default()
    };
    let mut t = Trainer::new(engine, &cfg).unwrap();
    let r = t.run().unwrap();
    // probes at steps 0, 4, 8
    assert_eq!(r.probes.len(), 3, "probe log {:?}", r.probes.len());
    for p in &r.probes {
        assert!(p.v_s > 0.0 && p.v_s.is_finite());
        assert!(p.v_act >= 0.0 && p.v_act.is_finite());
        assert!(p.s > 0.0 && p.s <= 1.0);
        for w in p.rho.windows(2) {
            assert!(w[1] >= w[0], "rho not monotone {:?}", p.rho);
        }
    }
    // s must have moved off its 1.0 init by the first update
    assert!(r.probes[0].s < 1.0);
    println!("controller probes ok (s: {:?})", r.probes.iter().map(|p| p.s).collect::<Vec<_>>());
}

fn check_checkpoint_roundtrip(engine: &Engine) {
    let cfg = TrainConfig {
        model: "tiny".into(),
        task: "sst2-sim".into(),
        method: Method::Exact,
        steps: 3,
        seed: 7,
        ..Default::default()
    };
    let mut t = Trainer::new(engine, &cfg).unwrap();
    let _ = t.run().unwrap();
    let path = std::env::temp_dir().join(format!("vcas_ckpt_{}.bin", std::process::id()));
    t.save_checkpoint(&path).unwrap();
    let mm = engine.model("tiny").unwrap();
    let loaded = ParamSet::load_bin(&path, &mm.param_specs).unwrap();
    for (a, b) in t.params.tensors.iter().zip(&loaded.tensors) {
        assert_eq!(a.data, b.data, "checkpoint mismatch in {}", a.name);
    }
    // finetune-from-checkpoint path: fresh trainer adopts the params
    let mut t2 = Trainer::new(engine, &cfg).unwrap();
    t2.set_params(loaded);
    let r2 = t2.run().unwrap();
    assert!(r2.losses[0].1.is_finite());
    let _ = std::fs::remove_file(&path);
    println!("checkpoint roundtrip ok");
}

fn check_cnn_path(engine: &Engine) {
    let cfg = TrainConfig {
        model: "cnn".into(),
        task: "images".into(),
        method: Method::Vcas,
        steps: 4,
        seed: 2,
        vcas: VcasConfig { freq: 2, ..Default::default() },
        ..Default::default()
    };
    let mut t = Trainer::new(engine, &cfg).unwrap();
    let r = t.run().unwrap();
    assert!(r.losses.iter().all(|&(_, l)| l.is_finite()));
    // CNN runs the degraded activation-only mode: nu stays empty/1
    let (rho, nu) = t.live_ratios();
    assert!(nu.is_empty());
    assert_eq!(rho.len(), 2); // one site per conv stage
    assert!(!r.probes.is_empty());
    println!("cnn path ok (rho {rho:?})");
}

fn check_mlm_path(engine: &Engine) {
    let cfg = TrainConfig {
        model: "tiny".into(),
        task: "mlm".into(),
        method: Method::Vcas,
        steps: 4,
        seed: 2,
        vcas: VcasConfig { freq: 2, ..Default::default() },
        eval_batches: 2,
        ..Default::default()
    };
    let mut t = Trainer::new(engine, &cfg).unwrap();
    let r = t.run().unwrap();
    assert!(r.losses.iter().all(|&(_, l)| l.is_finite() && l > 0.0));
    // MLM over a 512 vocab starts near ln(512) ~ 6.2
    assert!(r.losses[0].1 > 3.0, "initial mlm loss {:?}", r.losses[0]);
    println!("mlm path ok (loss {:.3})", r.losses[0].1);
}
