//! The telemetry determinism + introspection contract (PR 10).
//!
//! 1. **Determinism**: telemetry is a pure observer. With tracing on or
//!    off, every loss and parameter trajectory is bitwise identical —
//!    pinned across task kinds (cls / mlm / cnn) and both adaptive
//!    sampler families (vcas, approx_vjp).
//! 2. **Fidelity**: the trace stream opens with one `run_config` event,
//!    records one `step` event per training step, and the step losses
//!    survive the JSONL round trip bitwise (f32 → f64 → shortest
//!    round-trip Display).
//! 3. **Introspection**: the metrics registry counts steps, carries the
//!    vcas variance channels and the workspace-pool accounting, and
//!    renders as Prometheus text.

use std::sync::OnceLock;

use vcas::config::{Method, TrainConfig, VcasConfig};
use vcas::coordinator::Trainer;
use vcas::formats::json::Json;
use vcas::runtime::NativeBackend;

fn backend() -> &'static NativeBackend {
    static BACKEND: OnceLock<NativeBackend> = OnceLock::new();
    BACKEND.get_or_init(NativeBackend::with_default_models)
}

/// A small run config with telemetry explicitly pinned on or off (so the
/// ambient `VCAS_TRACE` of the test environment cannot skew the A/B).
fn cfg_for(model: &str, task: &str, method: Method, trace: bool) -> TrainConfig {
    let mut cfg = TrainConfig {
        model: model.into(),
        task: task.into(),
        method,
        steps: 4,
        seed: 31,
        eval_batches: 2,
        prefetch: Some(0),
        vcas: VcasConfig { freq: 2, ..Default::default() },
        ..Default::default()
    };
    cfg.strategy.vjp_rho = 0.5;
    cfg.telemetry.trace = Some(trace);
    // keep the A/B in memory: no trace file, no filesystem side channel
    cfg.telemetry.trace_out = String::new();
    cfg
}

// ---------------------------------------------------------------------------
// The determinism contract.
// ---------------------------------------------------------------------------

#[test]
fn tracing_on_or_off_trajectories_are_bitwise_identical() {
    for (model, task) in [("tiny", "sst2-sim"), ("tiny", "mlm"), ("cnn", "images")] {
        for method in [Method::Vcas, Method::ApproxVjp] {
            let what = format!("{model}/{task}/{}", method.name());
            let mut off =
                Trainer::new(backend(), &cfg_for(model, task, method.clone(), false)).unwrap();
            let r_off = off.run().unwrap();
            assert!(!off.telemetry().tracing(), "{what}: tracing should be off");
            let mut on =
                Trainer::new(backend(), &cfg_for(model, task, method.clone(), true)).unwrap();
            let r_on = on.run().unwrap();
            assert!(on.telemetry().tracing(), "{what}: tracing should be on");
            assert_eq!(r_off.losses.len(), r_on.losses.len(), "{what}: step counts");
            for (&(i, a), &(j, b)) in r_off.losses.iter().zip(&r_on.losses) {
                assert_eq!(i, j, "{what}: step index");
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{what}: loss diverged at step {i} (off {a} vs on {b})"
                );
            }
            assert_eq!(
                r_off.final_eval_acc, r_on.final_eval_acc,
                "{what}: eval accuracy diverged"
            );
            for (a, b) in off.params.tensors.iter().zip(&on.params.tensors) {
                assert_eq!(a.data, b.data, "{what}: final params differ in {}", a.name);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Trace-stream fidelity.
// ---------------------------------------------------------------------------

#[test]
fn trace_opens_with_run_config_and_step_losses_roundtrip_bitwise() {
    let cfg = cfg_for("tiny", "sst2-sim", Method::Vcas, true);
    let mut t = Trainer::new(backend(), &cfg).unwrap();
    let r = t.run().unwrap();
    // trace_out is empty, so the events are still buffered in memory
    let events = t.telemetry().drain_events();
    assert!(!events.is_empty());
    assert_eq!(t.telemetry().dropped_events(), 0);
    assert_eq!(events[0].scope, "run_config", "first event must be run_config");
    // the probe and backward spans are present, spans carry durations
    assert!(events.iter().any(|e| e.scope == "probe" && e.dur_us.is_some()));
    assert!(events.iter().any(|e| e.scope == "bwd" && e.dur_us.is_some()));
    assert!(events.iter().any(|e| e.scope == "fwd"), "eval forwards should be traced");

    // step losses through the actual JSONL serialization, bitwise
    let text = vcas::telemetry::to_jsonl(&events);
    let mut step_losses: Vec<f32> = Vec::new();
    for line in text.lines() {
        let obj = match Json::parse(line).unwrap() {
            Json::Obj(o) => o,
            other => panic!("trace line is not an object: {other:?}"),
        };
        if obj.get("scope") == Some(&Json::Str("step".to_string())) {
            match obj.get("loss") {
                Some(Json::Num(x)) => step_losses.push(*x as f32),
                other => panic!("step event without a numeric loss: {other:?}"),
            }
            assert!(
                matches!(obj.get("plan"), Some(Json::Str(_))),
                "step event must carry the executed plan"
            );
        }
    }
    assert_eq!(step_losses.len(), r.losses.len(), "one step event per training step");
    for (got, &(step, want)) in step_losses.iter().zip(&r.losses) {
        assert_eq!(
            got.to_bits(),
            want.to_bits(),
            "loss at step {step} mangled by the JSONL round trip ({got} vs {want})"
        );
    }
}

#[test]
fn trace_out_writes_parseable_jsonl() {
    let dir = std::env::temp_dir().join(format!("vcas-tel-test-{}", std::process::id()));
    let path = dir.join("trace.jsonl");
    let mut cfg = cfg_for("tiny", "sst2-sim", Method::ApproxVjp, true);
    cfg.telemetry.trace_out = path.to_string_lossy().to_string();
    Trainer::new(backend(), &cfg).unwrap().run().unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let mut scopes = Vec::new();
    for line in text.lines() {
        match Json::parse(line).unwrap() {
            Json::Obj(o) => match o.get("scope") {
                Some(Json::Str(s)) => scopes.push(s.clone()),
                other => panic!("trace line without scope: {other:?}"),
            },
            other => panic!("trace line is not an object: {other:?}"),
        }
    }
    assert_eq!(scopes.first().map(String::as_str), Some("run_config"));
    assert_eq!(scopes.iter().filter(|s| *s == "step").count(), cfg.steps);
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// Registry introspection.
// ---------------------------------------------------------------------------

#[test]
fn registry_counts_steps_and_renders_prometheus_text() {
    // tracing off on purpose: the metrics side must be live regardless
    let cfg = cfg_for("tiny", "sst2-sim", Method::Vcas, false);
    let mut t = Trainer::new(backend(), &cfg).unwrap();
    let r = t.run().unwrap();
    let reg = t.telemetry().registry();
    assert_eq!(reg.counter("train_steps").value(), cfg.steps as u64);
    let last = r.losses.last().unwrap().1;
    assert_eq!(reg.gauge("train_loss").value(), f64::from(last));
    // the probe published the vcas variance channels (freq=2, steps=4)
    assert!(reg.gauge("vcas_v_sgd").value().is_finite());
    let text = reg.prometheus_text();
    assert!(text.contains("train_steps 4"), "{text}");
    assert!(text.contains("train_loss"), "{text}");
    assert!(text.contains("vcas_v_sgd"), "{text}");
    // the workspace accounting satellite publishes pool gauges at run end
    assert!(text.contains("workspace_pooled_bufs"), "{text}");
    assert!(text.contains("matmul_calls_f32"), "{text}");
}
