//! The strategy-layer refactor contract (PR 9).
//!
//! 1. **Behavior preservation**: porting exact/vcas/sb/ub/uniform onto the
//!    `sampling::SamplerStrategy` trait must not change a single rng draw.
//!    Each replica below re-executes the *pre-refactor* trainer loop
//!    verbatim through public APIs (same `Pcg32::new(seed, 0x7EA1)` stream,
//!    same one-time source-seed draw, same per-grad `next_seed` schedule,
//!    same probe cadence) and the strategy-driven `Trainer` must match it
//!    bitwise — losses and final parameters — per task kind and thread
//!    count.
//! 2. **The approx-VJP family**: trains end to end, is unbiased in
//!    expectation, collapses to the exact trajectory at `vjp_rho = 1`, and
//!    reports a per-step variance trace.
//! 3. **The VR gate**: opt-in only; a permanently-closed gate reproduces
//!    the uniform baseline bitwise.

use std::sync::Arc;
use std::sync::OnceLock;

use vcas::config::{Method, TrainConfig, VcasConfig};
use vcas::coordinator::baselines::{ub_select, uniform_select, SbSelector};
use vcas::coordinator::pipeline::{ClsSource, ImgSource, Prefetcher, ProbeSplitSource};
use vcas::coordinator::{GradSample, Trainer, VcasController};
use vcas::data::batch::{sample_mlm_batch, ClsBatch};
use vcas::data::images::{generate_images, ImageSpec};
use vcas::data::tasks::{find, generate_cls, MarkovCorpus};
use vcas::formats::params::ParamSet;
use vcas::optim::{AdamW, LrSchedule, Optimizer, Sgdm};
use vcas::runtime::{Backend, ModelSession, NativeBackend};
use vcas::sampling::SamplerStrategy;
use vcas::util::rng::Pcg32;

fn backend() -> &'static NativeBackend {
    static BACKEND: OnceLock<NativeBackend> = OnceLock::new();
    BACKEND.get_or_init(NativeBackend::with_default_models)
}

// The pre-refactor trainer's constants, pinned here so a drive-by change
// to the trainer shows up as a trajectory mismatch.
const TRAIN_SET: usize = 4096;
const MLM_MASK_RATE: f64 = 0.15;

fn next_seed(rng: &mut Pcg32) -> i32 {
    (rng.next_u32() & 0x7FFF_FFFF) as i32
}

fn to_sample(grads: Vec<Vec<f32>>, act_norms: Vec<f32>, vw: Vec<f32>) -> GradSample {
    GradSample { grads, act_norms, vw }
}

/// Re-execute the pre-refactor per-step logic for a classification task
/// (methods exact/vcas/sb/ub/uniform) and return (losses, final params).
fn replica_cls(backend: &NativeBackend, cfg: &TrainConfig) -> (Vec<f32>, ParamSet) {
    let session = ModelSession::open(backend, &cfg.model).unwrap();
    let mut params = session.load_params().unwrap();
    let info = session.info().clone();
    let mut rng = Pcg32::new(cfg.seed, 0x7EA1);
    let depth = cfg.prefetch.expect("replica configs pin the prefetch depth");
    let (m, freq) = (cfg.vcas.m_repeats, cfg.vcas.freq);
    let split_probe = cfg.method == Method::Vcas && m > 0 && freq > 0;

    let spec = find(&cfg.task).unwrap();
    let train = Arc::new(generate_cls(
        &spec, session.vocab, session.seq_len, TRAIN_SET, cfg.seed ^ 0x11,
    ));
    let bsz = backend.main_batch();
    let src_seed = rng.next_u64();
    let (mut stream, mut probe) = if split_probe {
        (
            Prefetcher::new(
                ProbeSplitSource::train(
                    Box::new(ClsSource::new(train.clone(), bsz, src_seed)),
                    m,
                    freq,
                ),
                depth,
            ),
            Some(Prefetcher::new(
                ProbeSplitSource::probe(Box::new(ClsSource::new(train, bsz, src_seed)), m, freq),
                depth,
            )),
        )
    } else {
        (Prefetcher::new(ClsSource::new(train, bsz, src_seed), depth), None)
    };

    let mut ctrl = (cfg.method == Method::Vcas).then(|| {
        VcasController::new(cfg.vcas.clone(), session.n_layers, info.sampled_indices(), bsz)
    });
    let mut opt: Box<dyn Optimizer> = if cfg.optim.kind == "sgdm" {
        Box::new(Sgdm::new(&params, cfg.optim.momentum, cfg.optim.weight_decay))
    } else {
        Box::new(AdamW::new(
            &params,
            cfg.optim.beta1,
            cfg.optim.beta2,
            cfg.optim.eps,
            cfg.optim.weight_decay,
        ))
    };
    let sched =
        LrSchedule::from_config(&cfg.optim.schedule, cfg.optim.lr, cfg.optim.warmup_frac, cfg.steps);
    let sub_batch = backend.sub_batch();
    let mut sb = SbSelector::new(8 * bsz * 4, 1.0);
    let ones_l = vec![1.0f32; session.n_layers];
    let ones_s = vec![1.0f32; session.n_sampled];

    let mut out_losses = Vec::with_capacity(cfg.steps);
    for step in 0..cfg.steps {
        let loss = match cfg.method {
            Method::Exact => {
                let batch = stream.next().unwrap().into_cls().unwrap();
                let sw = vec![1.0 / batch.n as f32; batch.n];
                let seed = next_seed(&mut rng);
                let out = session
                    .fwd_bwd_cls(&params, &batch, &sw, seed, &ones_l, &ones_s, &ones_s)
                    .unwrap();
                opt.step(&mut params, &out.grads, sched.lr_at(step));
                out.loss
            }
            Method::Vcas => {
                let ctrl = ctrl.as_mut().unwrap();
                if ctrl.due(step) {
                    let (rho, _) = ctrl.train_ratios();
                    let nu_probe = ctrl.nu.clone();
                    let mut exact = Vec::with_capacity(m);
                    let mut sampled = Vec::with_capacity(m);
                    for _ in 0..m {
                        let batch =
                            probe.as_mut().unwrap().next().unwrap().into_cls().unwrap();
                        let sw = vec![1.0 / batch.n as f32; batch.n];
                        let seed = next_seed(&mut rng);
                        let g = session
                            .fwd_bwd_cls(&params, &batch, &sw, seed, &ones_l, &ones_s, &nu_probe)
                            .unwrap();
                        exact.push(to_sample(g.grads, g.act_norms, g.vw));
                        let mut reps = Vec::with_capacity(m);
                        for _ in 0..m {
                            let seed = next_seed(&mut rng);
                            let g = session
                                .fwd_bwd_cls(&params, &batch, &sw, seed, &rho, &ones_s, &nu_probe)
                                .unwrap();
                            reps.push(to_sample(g.grads, g.act_norms, g.vw));
                        }
                        sampled.push(reps);
                    }
                    ctrl.update(step, &exact, &sampled);
                }
                let (rho, nu) = ctrl.train_ratios();
                let batch = stream.next().unwrap().into_cls().unwrap();
                let sw = vec![1.0 / batch.n as f32; batch.n];
                let seed = next_seed(&mut rng);
                let out = session
                    .fwd_bwd_cls(&params, &batch, &sw, seed, &rho, &nu, &nu)
                    .unwrap();
                opt.step(&mut params, &out.grads, sched.lr_at(step));
                out.loss
            }
            _ => {
                // sb / ub / uniform: full-batch forward, select, sub-batch
                let batch = stream.next().unwrap().into_cls().unwrap();
                let (losses, scores) = session.fwd_loss_cls(&params, &batch).unwrap();
                let k = sub_batch;
                let sel = match cfg.method {
                    Method::Sb => sb.select(&losses, k, &mut rng).unwrap(),
                    Method::Ub => ub_select(&scores, k, &mut rng).unwrap(),
                    _ => uniform_select(batch.n, k, &mut rng),
                };
                let t = batch.seq_len;
                let mut x = Vec::with_capacity(k * t);
                let mut y = Vec::with_capacity(k);
                for &r in &sel.rows {
                    x.extend_from_slice(&batch.x[r * t..(r + 1) * t]);
                    y.push(batch.y[r]);
                }
                let sub = ClsBatch { n: k, seq_len: t, x, y, idx: vec![] };
                let seed = next_seed(&mut rng);
                let out = session
                    .fwd_bwd_cls(&params, &sub, &sel.weights, seed, &ones_l, &ones_s, &ones_s)
                    .unwrap();
                opt.step(&mut params, &out.grads, sched.lr_at(step));
                let mean_loss =
                    losses.iter().map(|&l| l as f64).sum::<f64>() / losses.len() as f64;
                mean_loss as f32
            }
        };
        out_losses.push(loss);
    }
    (out_losses, params)
}

/// Pre-refactor MLM loop (vcas): masking consumes the live trainer rng,
/// so batches interleave with per-grad seeds on one stream.
fn replica_mlm_vcas(backend: &NativeBackend, cfg: &TrainConfig) -> (Vec<f32>, ParamSet) {
    let session = ModelSession::open(backend, &cfg.model).unwrap();
    let mut params = session.load_params().unwrap();
    let info = session.info().clone();
    let mut rng = Pcg32::new(cfg.seed, 0x7EA1);
    let corpus = MarkovCorpus::new(session.vocab, 0.4, cfg.seed ^ 0x33);
    let bsz = backend.main_batch();
    let m = cfg.vcas.m_repeats;
    let mut ctrl =
        VcasController::new(cfg.vcas.clone(), session.n_layers, info.sampled_indices(), bsz);
    let mut opt = AdamW::new(
        &params,
        cfg.optim.beta1,
        cfg.optim.beta2,
        cfg.optim.eps,
        cfg.optim.weight_decay,
    );
    let sched =
        LrSchedule::from_config(&cfg.optim.schedule, cfg.optim.lr, cfg.optim.warmup_frac, cfg.steps);
    let ones_l = vec![1.0f32; session.n_layers];
    let ones_s = vec![1.0f32; session.n_sampled];
    let mut next_batch = |rng: &mut Pcg32| {
        sample_mlm_batch(&corpus, bsz, session.seq_len, session.vocab, MLM_MASK_RATE, rng)
    };

    let mut out_losses = Vec::with_capacity(cfg.steps);
    for step in 0..cfg.steps {
        if ctrl.due(step) {
            let (rho, _) = ctrl.train_ratios();
            let nu_probe = ctrl.nu.clone();
            let mut exact = Vec::with_capacity(m);
            let mut sampled = Vec::with_capacity(m);
            for _ in 0..m {
                let batch = next_batch(&mut rng);
                let seed = next_seed(&mut rng);
                let g = session
                    .fwd_bwd_mlm(&params, &batch, seed, &ones_l, &ones_s, &nu_probe)
                    .unwrap();
                exact.push(to_sample(g.grads, g.act_norms, g.vw));
                let mut reps = Vec::with_capacity(m);
                for _ in 0..m {
                    let seed = next_seed(&mut rng);
                    let g = session
                        .fwd_bwd_mlm(&params, &batch, seed, &rho, &ones_s, &nu_probe)
                        .unwrap();
                    reps.push(to_sample(g.grads, g.act_norms, g.vw));
                }
                sampled.push(reps);
            }
            ctrl.update(step, &exact, &sampled);
        }
        let (rho, nu) = ctrl.train_ratios();
        let batch = next_batch(&mut rng);
        let seed = next_seed(&mut rng);
        let out = session.fwd_bwd_mlm(&params, &batch, seed, &rho, &nu, &nu).unwrap();
        opt.step(&mut params, &out.grads, sched.lr_at(step));
        out_losses.push(out.loss);
    }
    (out_losses, params)
}

/// Pre-refactor CNN loop (vcas, activation-only controller, SGDM).
fn replica_cnn_vcas(backend: &NativeBackend, cfg: &TrainConfig) -> (Vec<f32>, ParamSet) {
    let session = ModelSession::open(backend, &cfg.model).unwrap();
    let mut params = session.load_params().unwrap();
    let info = session.info().clone();
    let mut rng = Pcg32::new(cfg.seed, 0x7EA1);
    let depth = cfg.prefetch.expect("replica configs pin the prefetch depth");
    let (m, freq) = (cfg.vcas.m_repeats, cfg.vcas.freq);

    let spec = ImageSpec {
        img: info.img,
        channels: info.in_ch,
        n_classes: info.n_classes,
        ..ImageSpec::default()
    };
    let train = Arc::new(generate_images(&spec, TRAIN_SET, cfg.seed ^ 0x11));
    let bsz = backend.cnn_batch();
    let src_seed = rng.next_u64();
    let (mut stream, mut probe) = (
        Prefetcher::new(
            ProbeSplitSource::train(Box::new(ImgSource::new(train.clone(), bsz, src_seed)), m, freq),
            depth,
        ),
        Prefetcher::new(
            ProbeSplitSource::probe(Box::new(ImgSource::new(train, bsz, src_seed)), m, freq),
            depth,
        ),
    );

    let mut vc = cfg.vcas.clone();
    vc.act_only = true; // the CNN path forces the activation-only mode
    let mut ctrl = VcasController::new(vc, session.n_layers, info.sampled_indices(), bsz);
    let mut opt = Sgdm::new(&params, cfg.optim.momentum, cfg.optim.weight_decay);
    let sched =
        LrSchedule::from_config(&cfg.optim.schedule, cfg.optim.lr, cfg.optim.warmup_frac, cfg.steps);
    let ones_sites = vec![1.0f32; session.n_layers];

    let mut out_losses = Vec::with_capacity(cfg.steps);
    for step in 0..cfg.steps {
        if ctrl.due(step) {
            let (rho, _) = ctrl.train_ratios();
            let mut exact = Vec::with_capacity(m);
            let mut sampled = Vec::with_capacity(m);
            for _ in 0..m {
                let batch = probe.next().unwrap().into_img().unwrap();
                let seed = next_seed(&mut rng);
                let g = session.cnn_fwd_bwd(&params, &batch, seed, &ones_sites).unwrap();
                exact.push(to_sample(g.grads, g.act_norms, vec![]));
                let mut reps = Vec::with_capacity(m);
                for _ in 0..m {
                    let seed = next_seed(&mut rng);
                    let g = session.cnn_fwd_bwd(&params, &batch, seed, &rho).unwrap();
                    reps.push(to_sample(g.grads, g.act_norms, vec![]));
                }
                sampled.push(reps);
            }
            ctrl.update(step, &exact, &sampled);
        }
        let (rho, _) = ctrl.train_ratios();
        let batch = stream.next().unwrap().into_img().unwrap();
        let seed = next_seed(&mut rng);
        let out = session.cnn_fwd_bwd(&params, &batch, seed, &rho).unwrap();
        opt.step(&mut params, &out.grads, sched.lr_at(step));
        out_losses.push(out.loss);
    }
    (out_losses, params)
}

fn assert_trajectory_bits_eq(
    replica: (Vec<f32>, ParamSet),
    trainer_losses: &[(usize, f32)],
    trainer_params: &ParamSet,
    what: &str,
) {
    let (losses, params) = replica;
    assert_eq!(losses.len(), trainer_losses.len(), "{what}: step counts differ");
    for (i, (rep, &(step, got))) in losses.iter().zip(trainer_losses).enumerate() {
        assert_eq!(step, i, "{what}: step index");
        assert_eq!(
            rep.to_bits(),
            got.to_bits(),
            "{what}: loss diverged at step {i} (replica {rep} vs trainer {got})"
        );
    }
    for (a, b) in params.tensors.iter().zip(&trainer_params.tensors) {
        assert_eq!(a.data, b.data, "{what}: final params differ in {}", a.name);
    }
}

// ---------------------------------------------------------------------------
// Behavior preservation: every pre-existing method, bitwise.
// ---------------------------------------------------------------------------

#[test]
fn cls_trajectories_bitwise_match_prerefactor_replica() {
    for method in [Method::Exact, Method::Vcas, Method::Sb, Method::Ub, Method::Uniform] {
        let cfg = TrainConfig {
            model: "tiny".into(),
            task: "sst2-sim".into(),
            method: method.clone(),
            steps: 5,
            seed: 13,
            eval_batches: 2,
            prefetch: Some(0),
            vcas: VcasConfig { freq: 2, ..Default::default() },
            ..Default::default()
        };
        let mut t = Trainer::new(backend(), &cfg).unwrap();
        let r = t.run().unwrap();
        let replica = replica_cls(backend(), &cfg);
        assert_trajectory_bits_eq(replica, &r.losses, &t.params, method.name());
    }
}

#[test]
fn cls_vcas_replica_matches_at_two_threads_and_custom_tau() {
    let b2 = NativeBackend::with_default_models().with_threads(2);
    let mut vcas = VcasConfig { freq: 2, ..Default::default() };
    vcas.tau_act *= 0.5;
    vcas.tau_w *= 2.0;
    let cfg = TrainConfig {
        model: "tiny".into(),
        task: "sst2-sim".into(),
        method: Method::Vcas,
        steps: 5,
        seed: 29,
        eval_batches: 2,
        prefetch: Some(0),
        vcas,
        ..Default::default()
    };
    let mut t = Trainer::new(&b2, &cfg).unwrap();
    let r = t.run().unwrap();
    let replica = replica_cls(&b2, &cfg);
    assert_trajectory_bits_eq(replica, &r.losses, &t.params, "vcas @ 2 threads, custom tau");
}

#[test]
fn cls_replica_survives_prefetch_depth() {
    // the refactor must not disturb the prefetch determinism contract:
    // depth 2 matches the same replica the depth-0 run matches
    let cfg = TrainConfig {
        model: "tiny".into(),
        task: "sst2-sim".into(),
        method: Method::Ub,
        steps: 5,
        seed: 37,
        eval_batches: 2,
        prefetch: Some(2),
        ..Default::default()
    };
    let mut t = Trainer::new(backend(), &cfg).unwrap();
    let r = t.run().unwrap();
    let replica = replica_cls(backend(), &cfg);
    assert_trajectory_bits_eq(replica, &r.losses, &t.params, "ub @ prefetch 2");
}

#[test]
fn mlm_vcas_trajectory_bitwise_matches_prerefactor_replica() {
    let cfg = TrainConfig {
        model: "tiny".into(),
        task: "mlm".into(),
        method: Method::Vcas,
        steps: 4,
        seed: 23,
        eval_batches: 2,
        vcas: VcasConfig { freq: 2, ..Default::default() },
        ..Default::default()
    };
    let mut t = Trainer::new(backend(), &cfg).unwrap();
    let r = t.run().unwrap();
    let replica = replica_mlm_vcas(backend(), &cfg);
    assert_trajectory_bits_eq(replica, &r.losses, &t.params, "mlm vcas");
}

#[test]
fn cnn_vcas_trajectory_bitwise_matches_prerefactor_replica() {
    let cfg = TrainConfig {
        model: "cnn".into(),
        task: "images".into(),
        method: Method::Vcas,
        steps: 4,
        seed: 19,
        eval_batches: 2,
        prefetch: Some(0),
        vcas: VcasConfig { freq: 2, ..Default::default() },
        ..Default::default()
    };
    let mut t = Trainer::new(backend(), &cfg).unwrap();
    let r = t.run().unwrap();
    let replica = replica_cnn_vcas(backend(), &cfg);
    assert_trajectory_bits_eq(replica, &r.losses, &t.params, "cnn vcas");
}

// ---------------------------------------------------------------------------
// The approx-VJP family.
// ---------------------------------------------------------------------------

#[test]
fn approx_vjp_trains_end_to_end_with_flops_reduction_and_trace() {
    let mut cfg = TrainConfig {
        model: "tiny".into(),
        task: "sst2-sim".into(),
        method: Method::ApproxVjp,
        steps: 5,
        seed: 7,
        eval_batches: 2,
        prefetch: Some(0),
        ..Default::default()
    };
    cfg.strategy.vjp_rho = 0.5;
    let mut t = Trainer::new(backend(), &cfg).unwrap();
    assert_eq!(t.strategy().name(), "approx_vjp");
    let r = t.run().unwrap();
    assert!(r.losses.iter().all(|&(_, l)| l.is_finite()), "losses {:?}", r.losses);
    assert!(
        r.flops_reduction > 0.0,
        "sketched dgrad must charge fewer FLOPs (reduction {})",
        r.flops_reduction
    );
    // per-step sketch-variance telemetry, one entry per training step
    let trace = t.strategy().variance_trace();
    assert_eq!(trace.len(), cfg.steps);
    assert!(trace.iter().all(|&(_, v)| v.is_finite() && v >= 0.0), "trace {trace:?}");
    assert!(trace.iter().any(|&(_, v)| v > 0.0), "sketch variance all zero: {trace:?}");
    // and the CNN path runs too (variance is discarded there by design)
    let mut ccfg = TrainConfig {
        model: "cnn".into(),
        task: "images".into(),
        method: Method::ApproxVjp,
        steps: 3,
        seed: 7,
        eval_batches: 2,
        prefetch: Some(0),
        ..Default::default()
    };
    ccfg.strategy.vjp_rho = 0.5;
    let r = Trainer::new(backend(), &ccfg).unwrap().run().unwrap();
    assert!(r.losses.iter().all(|&(_, l)| l.is_finite()));
}

#[test]
fn approx_vjp_at_ratio_one_is_bitwise_exact() {
    // vjp_rho = 1 keeps every column at scale 1: the sketch branch is
    // bypassed, no vjp rng draw happens, and the whole trajectory —
    // including the FLOPs ledger, since (1 + 1)/2 = 1 — equals exact's.
    let base = TrainConfig {
        model: "tiny".into(),
        task: "sst2-sim".into(),
        method: Method::Exact,
        steps: 4,
        seed: 11,
        eval_batches: 2,
        prefetch: Some(0),
        ..Default::default()
    };
    let re = Trainer::new(backend(), &base).unwrap().run().unwrap();
    let mut vcfg = TrainConfig { method: Method::ApproxVjp, ..base };
    vcfg.strategy.vjp_rho = 1.0;
    let rv = Trainer::new(backend(), &vcfg).unwrap().run().unwrap();
    assert_eq!(re.losses, rv.losses, "ratio-1 approx_vjp must equal exact bitwise");
    assert_eq!(re.final_eval_acc, rv.final_eval_acc);
    assert_eq!(re.flops_actual, rv.flops_actual);
}

#[test]
fn approx_vjp_grads_unbiased_over_seeds_end_to_end() {
    // Mean of the sketched full-model gradient over many vjp seeds must
    // approach the exact gradient: backward VJP maps are linear in the
    // incoming gradient, so per-linear sketch unbiasedness composes
    // through the whole stack.
    let sess = ModelSession::open(backend(), "tiny").unwrap();
    let params = sess.load_params().unwrap();
    let spec = find("sst2-sim").unwrap();
    let ds = generate_cls(&spec, sess.vocab, sess.seq_len, 64, 5);
    let idx: Vec<usize> = (0..backend().main_batch()).collect();
    let batch = vcas::data::batch::gather_cls(&ds, &idx);
    let sw = vec![1.0 / batch.n as f32; batch.n];
    let ones_l = vec![1.0f32; sess.n_layers];
    let ones_s = vec![1.0f32; sess.n_sampled];
    let exact = sess
        .fwd_bwd_cls(&params, &batch, &sw, 0, &ones_l, &ones_s, &ones_s)
        .unwrap();

    let reps = 400usize;
    let mut mean: Vec<Vec<f64>> = exact.grads.iter().map(|g| vec![0.0; g.len()]).collect();
    for seed in 0..reps {
        let out = sess.fwd_bwd_cls_vjp(&params, &batch, &sw, seed as i32, 0.5).unwrap();
        // the forward is untouched by the sketch
        assert_eq!(out.loss.to_bits(), exact.loss.to_bits());
        // nu = 1 makes Eq.3 variance 0, so vw carries pure sketch variance
        assert!(out.vw.iter().sum::<f32>() > 0.0, "sketch variance missing");
        for (acc, g) in mean.iter_mut().zip(&out.grads) {
            for (a, &x) in acc.iter_mut().zip(g) {
                *a += x as f64;
            }
        }
    }
    let (mut err, mut norm) = (0.0f64, 0.0f64);
    for (acc, g) in mean.iter().zip(&exact.grads) {
        for (&a, &x) in acc.iter().zip(g) {
            let d = a / reps as f64 - x as f64;
            err += d * d;
            norm += (x as f64) * (x as f64);
        }
    }
    let rel = (err / norm.max(1e-30)).sqrt();
    assert!(rel < 0.15, "approx-VJP mean grad off by rel {rel:.4} over {reps} seeds");
}

// ---------------------------------------------------------------------------
// The VR gate (opt-in).
// ---------------------------------------------------------------------------

#[test]
fn vr_gate_closed_reproduces_uniform_and_stays_opt_in() {
    let base = TrainConfig {
        model: "tiny".into(),
        task: "sst2-sim".into(),
        method: Method::Ub,
        steps: 5,
        seed: 41,
        eval_batches: 2,
        prefetch: Some(0),
        ..Default::default()
    };
    assert!(!base.strategy.vr_gate, "the gate must default off");
    // a gate that never opens degrades UB to the uniform baseline bitwise
    let mut gated = base.clone();
    gated.strategy.vr_gate = true;
    gated.strategy.vr_threshold = 1e9;
    let rg = Trainer::new(backend(), &gated).unwrap().run().unwrap();
    let runi = Trainer::new(
        backend(),
        &TrainConfig { method: Method::Uniform, ..base.clone() },
    )
    .unwrap()
    .run()
    .unwrap();
    assert_eq!(rg.losses, runi.losses, "closed gate must equal uniform bitwise");
    // while plain (ungated) UB takes a different trajectory
    let rub = Trainer::new(backend(), &base).unwrap().run().unwrap();
    assert_ne!(rub.losses, rg.losses, "gate off must keep real UB selection");
}
